//! Thread-dispersed locality-preserving block scheduler with work stealing
//! (paper §IV-C).
//!
//! The graph is split into blocks of consecutive vertices with approximately
//! equal *edge* counts. Under the paper's assignment each thread owns a
//! contiguous run of blocks (locality: a thread walks consecutive
//! neighborhoods; dispersion: the t runs start far apart in the ID space).
//! A thread that exhausts its run steals whole blocks from the thread with
//! the most remaining work. Alternative assignments are provided for the
//! scheduler ablation bench.

use crate::graph::CsrGraph;
use crate::VertexId;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Block assignment policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Assignment {
    /// Paper §IV-C: contiguous runs of blocks per thread.
    DispersedContiguous,
    /// Block i → thread i mod t (destroys per-thread locality).
    Interleaved,
    /// Single shared queue (no affinity at all).
    SharedQueue,
}

/// A block of consecutive vertices `[start, end)`.
pub type Block = (VertexId, VertexId);

/// The thread-dispersed block scheduler with work stealing (§IV-C).
pub struct BlockScheduler {
    blocks: Vec<Block>,
    /// Per-thread `[lo, hi)` index ranges into `blocks` plus a cursor.
    ranges: Vec<(usize, usize)>,
    cursors: Vec<AtomicUsize>,
    steals: AtomicUsize,
}

impl BlockScheduler {
    /// Split `g` into ≈`num_threads * blocks_per_thread` equal-edge blocks
    /// and assign them per `policy`.
    pub fn new(
        g: &CsrGraph,
        num_threads: usize,
        blocks_per_thread: usize,
        policy: Assignment,
    ) -> Self {
        let blocks = split_equal_edges(g, num_threads * blocks_per_thread.max(1));
        Self::from_blocks(blocks, num_threads, policy)
    }

    /// Scheduler over pre-split blocks, assigned per `policy`.
    pub fn from_blocks(mut blocks: Vec<Block>, num_threads: usize, policy: Assignment) -> Self {
        match policy {
            Assignment::DispersedContiguous => {
                // blocks already in vertex order; contiguous runs per thread
            }
            Assignment::Interleaved => {
                // reorder so thread i's run contains blocks i, i+t, i+2t, ...
                let t = num_threads;
                let mut reordered = Vec::with_capacity(blocks.len());
                for tid in 0..t {
                    let mut j = tid;
                    while j < blocks.len() {
                        reordered.push(blocks[j]);
                        j += t;
                    }
                }
                blocks = reordered;
            }
            Assignment::SharedQueue => {}
        }
        let nb = blocks.len();
        let ranges: Vec<(usize, usize)> = match policy {
            Assignment::SharedQueue => {
                // one global range owned by thread 0; everyone "steals"
                let mut r = vec![(0usize, 0usize); num_threads];
                r[0] = (0, nb);
                r
            }
            _ => {
                // contiguous partition of the (possibly reordered) block list
                let per = nb.div_ceil(num_threads.max(1));
                (0..num_threads)
                    .map(|tid| ((tid * per).min(nb), ((tid + 1) * per).min(nb)))
                    .collect()
            }
        };
        let cursors = ranges.iter().map(|&(lo, _)| AtomicUsize::new(lo)).collect();
        Self {
            blocks,
            ranges,
            cursors,
            steals: AtomicUsize::new(0),
        }
    }

    /// Total blocks under management.
    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Steal events observed so far.
    pub fn steal_count(&self) -> usize {
        self.steals.load(Ordering::Relaxed)
    }

    /// Claim the next block for `tid`: own range first, then steal from the
    /// victim with the most remaining blocks.
    pub fn next_block(&self, tid: usize) -> Option<Block> {
        // own range
        if let Some(b) = self.claim_from(tid) {
            return Some(b);
        }
        // work stealing: pick the victim with the most remaining work
        loop {
            let mut best: Option<(usize, usize)> = None; // (victim, remaining)
            for v in 0..self.ranges.len() {
                if v == tid {
                    continue;
                }
                let (_, hi) = self.ranges[v];
                let cur = self.cursors[v].load(Ordering::Relaxed);
                let remaining = hi.saturating_sub(cur);
                if remaining > 0 && best.map(|(_, r)| remaining > r).unwrap_or(true) {
                    best = Some((v, remaining));
                }
            }
            match best {
                None => return None,
                Some((victim, _)) => {
                    if let Some(b) = self.claim_from(victim) {
                        self.steals.fetch_add(1, Ordering::Relaxed);
                        return Some(b);
                    }
                    // raced; rescan
                }
            }
        }
    }

    fn claim_from(&self, owner: usize) -> Option<Block> {
        let (_, hi) = self.ranges[owner];
        let idx = self.cursors[owner].fetch_add(1, Ordering::Relaxed);
        if idx < hi {
            Some(self.blocks[idx])
        } else {
            // undo overshoot is unnecessary: cursor only ever grows, and
            // remaining() uses saturating_sub
            None
        }
    }
}

/// Split vertices into `target_blocks` contiguous ranges of ≈equal edge
/// count (always at least one vertex per block).
pub fn split_equal_edges(g: &CsrGraph, target_blocks: usize) -> Vec<Block> {
    let n = g.num_vertices();
    if n == 0 {
        return vec![];
    }
    let total_edges = g.num_edge_slots() as u64;
    let target = target_blocks.max(1) as u64;
    let per_block = (total_edges / target).max(1);
    let offsets = g.offsets();
    let mut blocks = Vec::with_capacity(target_blocks);
    let mut start = 0usize;
    let mut next_cut = per_block;
    for v in 0..n {
        if offsets[v + 1] >= next_cut && v + 1 > start {
            blocks.push((start as VertexId, (v + 1) as VertexId));
            start = v + 1;
            next_cut = offsets[v + 1] + per_block;
        }
    }
    if start < n {
        blocks.push((start as VertexId, n as VertexId));
    }
    blocks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{rmat, GenConfig};
    use crate::par::run_threads;
    use std::collections::HashSet;
    use std::sync::Mutex;

    fn test_graph() -> CsrGraph {
        rmat::generate(&GenConfig {
            scale: 10,
            avg_degree: 8,
            seed: 3,
        })
    }

    #[test]
    fn blocks_cover_all_vertices_once() {
        let g = test_graph();
        let blocks = split_equal_edges(&g, 64);
        let mut covered = 0usize;
        let mut prev_end = 0;
        for &(s, e) in &blocks {
            assert_eq!(s, prev_end);
            assert!(e > s);
            covered += (e - s) as usize;
            prev_end = e;
        }
        assert_eq!(covered, g.num_vertices());
    }

    #[test]
    fn blocks_have_balanced_edges() {
        let g = test_graph();
        let blocks = split_equal_edges(&g, 32);
        let total = g.num_edge_slots() as f64;
        let target = total / 32.0;
        let max_deg = g.max_degree() as f64;
        for &(s, e) in &blocks {
            let edges: u64 = (s..e).map(|v| g.degree(v)).sum::<usize>() as u64;
            // a block can exceed target by at most one vertex's degree
            assert!(
                (edges as f64) <= target + max_deg + 1.0,
                "block ({s},{e}) has {edges} edges, target {target}"
            );
        }
    }

    fn drain_all(policy: Assignment, threads: usize) -> usize {
        let g = test_graph();
        let sched = BlockScheduler::new(&g, threads, 8, policy);
        let claimed = Mutex::new(HashSet::new());
        run_threads(threads, |tid| {
            while let Some(b) = sched.next_block(tid) {
                let fresh = claimed.lock().unwrap().insert(b);
                assert!(fresh, "block {b:?} claimed twice");
            }
        });
        let n: usize = claimed
            .lock()
            .unwrap()
            .iter()
            .map(|&(s, e)| (e - s) as usize)
            .sum();
        assert_eq!(n, g.num_vertices());
        let count = claimed.lock().unwrap().len();
        count
    }

    #[test]
    fn all_policies_drain_every_block_exactly_once() {
        for policy in [
            Assignment::DispersedContiguous,
            Assignment::Interleaved,
            Assignment::SharedQueue,
        ] {
            for threads in [1, 2, 4] {
                drain_all(policy, threads);
            }
        }
    }

    #[test]
    fn stealing_happens_for_shared_queue() {
        let g = test_graph();
        let sched = BlockScheduler::new(&g, 4, 8, Assignment::SharedQueue);
        // drain only from a non-owner thread: every claimed block is a steal
        let mut claimed = 0usize;
        while sched.next_block(3).is_some() {
            claimed += 1;
        }
        assert_eq!(claimed, sched.num_blocks());
        assert_eq!(sched.steal_count(), claimed);
    }

    #[test]
    fn empty_graph_yields_no_blocks() {
        let g = CsrGraph::from_parts(vec![0], vec![]).unwrap();
        let sched = BlockScheduler::new(&g, 2, 4, Assignment::DispersedContiguous);
        assert_eq!(sched.num_blocks(), 0);
        assert!(sched.next_block(0).is_none());
    }

    #[test]
    fn contiguous_assignment_is_dispersed() {
        // thread 0's first block starts at vertex 0; thread t-1's first block
        // starts deep into the ID space
        let g = test_graph();
        let sched = BlockScheduler::new(&g, 4, 8, Assignment::DispersedContiguous);
        let b0 = sched.next_block(0).unwrap();
        let b3 = sched.next_block(3).unwrap();
        assert_eq!(b0.0, 0);
        assert!(b3.0 > g.num_vertices() as u32 / 2);
    }
}
