//! CPU/NUMA topology discovery and thread placement for the shard workers.
//!
//! The dynamic engine's shard→worker affinity is stable by construction
//! (shard `i` always runs on pool worker `i` — see
//! [`WorkerPool`](super::pool::WorkerPool)), which makes worker placement
//! *meaningful*: if worker `i` is pinned to a core and shard `i`'s
//! adjacency arena is first-touched from that worker, the shard's entire
//! hot path — list headers, slot lines, its stripe of the atomic
//! `partner[]` — is resident on that core's NUMA node. This module supplies
//! the three ingredients:
//!
//! * **discovery** — [`Topology::discover`] parses
//!   `/sys/devices/system/node/*/cpulist` (no dependencies, no syscalls
//!   beyond file reads) and falls back to a single synthetic node covering
//!   every schedulable CPU when sysfs is absent (non-Linux, containers,
//!   stripped-down CI runners);
//! * **policy** — [`PinPolicy`] picks how workers map onto the topology:
//!   `none` (default: the scheduler decides, nothing is pinned), `compact`
//!   (fill one node before spilling to the next — minimizes cross-node
//!   traffic for few workers), `spread` (round-robin across nodes —
//!   maximizes aggregate memory bandwidth);
//! * **mechanism** — [`pin_current_thread`] (`sched_setaffinity` on the
//!   calling thread) and [`advise_hugepages`] (`madvise(MADV_HUGEPAGE)` on
//!   a slab) via direct `extern "C"` libc declarations, since the crate
//!   vendors everything and `std` already links libc on every supported
//!   platform. Both degrade to no-ops that report failure (`false`) rather
//!   than erroring: placement is an optimization, never a correctness
//!   dependency, and every caller must behave identically when it fails.
//!
//! Pinning must be **invisible to results**: the engine asserts bit-for-bit
//! identical matchings across policies (see `prop_dynamic.rs`), so the only
//! observable differences are wall time and the placement gauges this
//! module registers (`skipper_topology_nodes`, `skipper_topology_cpus`).

use crate::obs::metrics;

/// How pool workers are placed onto the discovered topology.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PinPolicy {
    /// No pinning: threads float wherever the OS scheduler puts them.
    /// The default — placement is strictly opt-in.
    #[default]
    None,
    /// Fill node 0's CPUs first, then node 1's, … — workers stay on as few
    /// nodes as possible, so small pools share one socket's cache.
    Compact,
    /// Round-robin workers across nodes — large pools draw on every
    /// node's memory bandwidth.
    Spread,
}

impl PinPolicy {
    /// Parse a CLI spelling (`none` / `compact` / `spread`).
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "none" => Ok(PinPolicy::None),
            "compact" => Ok(PinPolicy::Compact),
            "spread" => Ok(PinPolicy::Spread),
            other => Err(format!("unknown pin policy {other:?} (none|compact|spread)")),
        }
    }

    /// The canonical CLI/report spelling.
    pub fn name(&self) -> &'static str {
        match self {
            PinPolicy::None => "none",
            PinPolicy::Compact => "compact",
            PinPolicy::Spread => "spread",
        }
    }
}

/// One NUMA node: its id and the schedulable CPUs it holds.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NodeInfo {
    /// Kernel node id (the `N` of `/sys/devices/system/node/nodeN`).
    pub id: usize,
    /// CPU ids on this node, ascending.
    pub cpus: Vec<usize>,
}

/// A worker's placement: the core it is pinned to and that core's node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CpuSlot {
    /// CPU id to pin to.
    pub cpu: usize,
    /// NUMA node that CPU belongs to.
    pub node: usize,
}

/// The machine's CPU/NUMA layout as far as placement cares: which CPUs
/// exist and how they group into nodes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Topology {
    /// Nodes with at least one CPU, ascending by id. Never empty.
    pub nodes: Vec<NodeInfo>,
    /// True when this came from sysfs, false for the synthetic fallback.
    pub from_sysfs: bool,
}

impl Topology {
    /// Discover the topology from `/sys/devices/system/node`. Any failure —
    /// non-Linux, masked sysfs, unparsable files, a node list with no CPUs —
    /// yields the single-node [`fallback`](Self::fallback) instead of an
    /// error: placement code never needs to handle "no topology".
    pub fn discover() -> Self {
        Self::from_sysfs_root("/sys/devices/system/node").unwrap_or_else(Self::fallback)
    }

    /// Parse a sysfs `node/` directory (exposed for tests, which point it
    /// at a synthetic tree).
    pub fn from_sysfs_root(root: &str) -> Option<Self> {
        let online = std::fs::read_to_string(format!("{root}/online")).ok()?;
        let ids = parse_cpu_list(online.trim())?;
        let mut nodes = Vec::new();
        for id in ids {
            let list = std::fs::read_to_string(format!("{root}/node{id}/cpulist")).ok()?;
            let cpus = parse_cpu_list(list.trim())?;
            if !cpus.is_empty() {
                nodes.push(NodeInfo { id, cpus });
            }
        }
        if nodes.is_empty() {
            return None;
        }
        Some(Self { nodes, from_sysfs: true })
    }

    /// One synthetic node holding every schedulable CPU — what single-node
    /// machines genuinely look like, and what every `--pin` path degrades
    /// to when discovery fails.
    pub fn fallback() -> Self {
        let ncpus = std::thread::available_parallelism().map_or(1, |n| n.get());
        Self {
            nodes: vec![NodeInfo { id: 0, cpus: (0..ncpus).collect() }],
            from_sysfs: false,
        }
    }

    /// Number of NUMA nodes with CPUs.
    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Total schedulable CPUs across nodes.
    pub fn num_cpus(&self) -> usize {
        self.nodes.iter().map(|n| n.cpus.len()).sum()
    }

    /// Assign `workers` placement slots under `policy`. `None` policy (or a
    /// topology with zero CPUs, which [`fallback`](Self::fallback) rules
    /// out) yields all-`None`: nothing gets pinned. More workers than CPUs
    /// wrap around — oversubscription pins them anyway, preserving the
    /// shard→node mapping that the first-touch arenas rely on.
    pub fn plan(&self, policy: PinPolicy, workers: usize) -> Vec<Option<CpuSlot>> {
        if policy == PinPolicy::None || self.num_cpus() == 0 {
            return vec![None; workers];
        }
        match policy {
            PinPolicy::None => unreachable!(),
            PinPolicy::Compact => {
                // node-major flattening: node 0's CPUs, then node 1's, …
                let flat: Vec<CpuSlot> = self
                    .nodes
                    .iter()
                    .flat_map(|n| n.cpus.iter().map(|&cpu| CpuSlot { cpu, node: n.id }))
                    .collect();
                (0..workers).map(|i| Some(flat[i % flat.len()])).collect()
            }
            PinPolicy::Spread => (0..workers)
                .map(|i| {
                    let node = &self.nodes[i % self.nodes.len()];
                    let cpu = node.cpus[(i / self.nodes.len()) % node.cpus.len()];
                    Some(CpuSlot { cpu, node: node.id })
                })
                .collect(),
        }
    }

    /// Register and set the topology gauges on the global metrics registry
    /// (`skipper_topology_nodes`, `skipper_topology_cpus`). Idempotent —
    /// re-registration returns the same instruments.
    pub fn publish_gauges(&self) {
        let reg = metrics::global();
        reg.gauge("skipper_topology_nodes", "NUMA nodes with CPUs discovered at engine construction")
            .set(self.num_nodes() as u64);
        reg.gauge("skipper_topology_cpus", "Schedulable CPUs discovered at engine construction")
            .set(self.num_cpus() as u64);
    }
}

/// Parse a kernel cpulist (`"0-3,8,10-11"`) into ascending CPU ids.
/// Returns `None` on any malformed field; an empty string is an empty list
/// (how sysfs spells a memory-only node).
pub fn parse_cpu_list(s: &str) -> Option<Vec<usize>> {
    let mut out = Vec::new();
    for field in s.split(',') {
        let field = field.trim();
        if field.is_empty() {
            continue;
        }
        match field.split_once('-') {
            Some((lo, hi)) => {
                let lo: usize = lo.trim().parse().ok()?;
                let hi: usize = hi.trim().parse().ok()?;
                if hi < lo {
                    return None;
                }
                out.extend(lo..=hi);
            }
            None => out.push(field.trim().parse().ok()?),
        }
    }
    out.sort_unstable();
    out.dedup();
    Some(out)
}

// ---------------------------------------------------------------------------
// mechanism: sched_setaffinity / sched_getcpu / madvise
// ---------------------------------------------------------------------------

/// Widest CPU id the affinity mask covers (`[u64; 16]` = 1024 CPUs, the
/// kernel's historical `CPU_SETSIZE`).
const MASK_WORDS: usize = 16;

#[cfg(target_os = "linux")]
mod sys {
    extern "C" {
        pub fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
        pub fn sched_getcpu() -> i32;
        pub fn madvise(addr: *mut core::ffi::c_void, length: usize, advice: i32) -> i32;
    }
    /// `MADV_HUGEPAGE` from `<linux/mman.h>` — ask for transparent huge
    /// pages on the range.
    pub const MADV_HUGEPAGE: i32 = 14;
}

/// Pin the calling thread to `cpu`. Returns whether the kernel accepted the
/// mask; `false` on non-Linux, for CPU ids beyond the mask, or when the
/// syscall is refused (cgroup cpusets, seccomp). Callers treat `false` as
/// "run unpinned", never as an error.
pub fn pin_current_thread(cpu: usize) -> bool {
    #[cfg(target_os = "linux")]
    {
        if cpu >= MASK_WORDS * 64 {
            return false;
        }
        let mut mask = [0u64; MASK_WORDS];
        mask[cpu / 64] = 1u64 << (cpu % 64);
        // pid 0 = the calling thread
        unsafe {
            sys::sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) == 0
        }
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = cpu;
        false
    }
}

/// Reset the calling thread's affinity to every CPU in `topo` — undoes a
/// [`pin_current_thread`] (benches pin, measure, and restore).
pub fn unpin_current_thread(topo: &Topology) -> bool {
    #[cfg(target_os = "linux")]
    {
        let mut mask = [0u64; MASK_WORDS];
        for node in &topo.nodes {
            for &cpu in &node.cpus {
                if cpu < MASK_WORDS * 64 {
                    mask[cpu / 64] |= 1u64 << (cpu % 64);
                }
            }
        }
        if mask.iter().all(|&w| w == 0) {
            return false;
        }
        unsafe {
            sys::sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) == 0
        }
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = topo;
        false
    }
}

/// The CPU the calling thread is on right now (`sched_getcpu`), `None` on
/// non-Linux.
pub fn current_cpu() -> Option<usize> {
    #[cfg(target_os = "linux")]
    {
        let cpu = unsafe { sys::sched_getcpu() };
        usize::try_from(cpu).ok()
    }
    #[cfg(not(target_os = "linux"))]
    {
        None
    }
}

/// Assumed kernel page size for aligning `madvise` ranges inward. On
/// kernels with larger pages the aligned range is simply rejected
/// (`EINVAL`) and we report `false` — advice, not correctness.
const PAGE: usize = 4096;

/// Ask the kernel to back `[ptr, ptr+len)` with transparent huge pages
/// (`madvise(MADV_HUGEPAGE)`). The range is aligned *inward* to page
/// boundaries since heap slabs rarely start page-aligned; ranges smaller
/// than one page (after alignment) are skipped. Returns whether the advice
/// was accepted — `false` is always safe to ignore.
pub fn advise_hugepages(ptr: *const u8, len: usize) -> bool {
    #[cfg(target_os = "linux")]
    {
        let start = ptr as usize;
        let aligned_start = start.checked_add(PAGE - 1).map(|s| s & !(PAGE - 1));
        let Some(aligned_start) = aligned_start else { return false };
        let end = (start + len) & !(PAGE - 1);
        if end <= aligned_start {
            return false; // less than one full page inside the slab
        }
        unsafe {
            sys::madvise(
                aligned_start as *mut core::ffi::c_void,
                end - aligned_start,
                sys::MADV_HUGEPAGE,
            ) == 0
        }
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = (ptr, len);
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_list_parses_kernel_spellings() {
        assert_eq!(parse_cpu_list("0-3,8,10-11").unwrap(), vec![0, 1, 2, 3, 8, 10, 11]);
        assert_eq!(parse_cpu_list("0").unwrap(), vec![0]);
        assert_eq!(parse_cpu_list("").unwrap(), Vec::<usize>::new());
        assert_eq!(parse_cpu_list("3,1,2,2").unwrap(), vec![1, 2, 3]);
        assert!(parse_cpu_list("4-2").is_none());
        assert!(parse_cpu_list("a-b").is_none());
        assert!(parse_cpu_list("1,x").is_none());
    }

    #[test]
    fn policy_parses_and_round_trips() {
        for p in [PinPolicy::None, PinPolicy::Compact, PinPolicy::Spread] {
            assert_eq!(PinPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(PinPolicy::parse("sideways").is_err());
        assert_eq!(PinPolicy::default(), PinPolicy::None);
    }

    #[test]
    fn discovery_always_yields_a_usable_topology() {
        // on any host — sysfs or fallback — there is at least one node
        // holding at least one CPU, so plan() never divides by zero
        let topo = Topology::discover();
        assert!(topo.num_nodes() >= 1);
        assert!(topo.num_cpus() >= 1);
        for node in &topo.nodes {
            assert!(!node.cpus.is_empty());
        }
    }

    #[test]
    fn fallback_is_one_node_covering_all_cpus() {
        let topo = Topology::fallback();
        assert_eq!(topo.num_nodes(), 1);
        assert!(!topo.from_sysfs);
        assert_eq!(topo.num_cpus(), topo.nodes[0].cpus.len());
    }

    fn two_socket() -> Topology {
        Topology {
            nodes: vec![
                NodeInfo { id: 0, cpus: vec![0, 1, 2, 3] },
                NodeInfo { id: 1, cpus: vec![4, 5, 6, 7] },
            ],
            from_sysfs: true,
        }
    }

    #[test]
    fn none_policy_pins_nothing() {
        assert!(two_socket().plan(PinPolicy::None, 6).iter().all(Option::is_none));
    }

    #[test]
    fn compact_fills_a_node_before_spilling() {
        let plan = two_socket().plan(PinPolicy::Compact, 6);
        let slots: Vec<CpuSlot> = plan.into_iter().map(Option::unwrap).collect();
        assert_eq!(
            slots.iter().map(|s| s.cpu).collect::<Vec<_>>(),
            vec![0, 1, 2, 3, 4, 5]
        );
        assert_eq!(
            slots.iter().map(|s| s.node).collect::<Vec<_>>(),
            vec![0, 0, 0, 0, 1, 1]
        );
    }

    #[test]
    fn spread_round_robins_nodes() {
        let plan = two_socket().plan(PinPolicy::Spread, 6);
        let slots: Vec<CpuSlot> = plan.into_iter().map(Option::unwrap).collect();
        assert_eq!(
            slots.iter().map(|s| s.node).collect::<Vec<_>>(),
            vec![0, 1, 0, 1, 0, 1]
        );
        assert_eq!(
            slots.iter().map(|s| s.cpu).collect::<Vec<_>>(),
            vec![0, 4, 1, 5, 2, 6]
        );
    }

    #[test]
    fn oversubscription_wraps_instead_of_failing() {
        let topo = Topology {
            nodes: vec![NodeInfo { id: 0, cpus: vec![0] }],
            from_sysfs: false,
        };
        let plan = topo.plan(PinPolicy::Compact, 4);
        assert!(plan.iter().all(|s| s == &Some(CpuSlot { cpu: 0, node: 0 })));
        let plan = topo.plan(PinPolicy::Spread, 3);
        assert!(plan.iter().all(|s| s == &Some(CpuSlot { cpu: 0, node: 0 })));
    }

    #[test]
    fn synthetic_sysfs_tree_parses() {
        let dir = std::env::temp_dir().join(format!("skipper_topo_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        for (node, list) in [(0, "0-1\n"), (1, "2-3\n")] {
            let d = dir.join(format!("node{node}"));
            std::fs::create_dir_all(&d).unwrap();
            std::fs::write(d.join("cpulist"), list).unwrap();
        }
        std::fs::write(dir.join("online"), "0-1\n").unwrap();
        let topo = Topology::from_sysfs_root(dir.to_str().unwrap()).unwrap();
        assert!(topo.from_sysfs);
        assert_eq!(topo.num_nodes(), 2);
        assert_eq!(topo.nodes[1].cpus, vec![2, 3]);
        // a missing cpulist file fails discovery (caller falls back)
        std::fs::remove_file(dir.join("node1").join("cpulist")).unwrap();
        assert!(Topology::from_sysfs_root(dir.to_str().unwrap()).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pinning_mechanism_never_panics() {
        // pin to the CPU we are on (or CPU 0), then restore — the calls may
        // be refused (non-Linux, cgroup masks) but must never crash, and a
        // refused pin must leave the thread schedulable
        let topo = Topology::discover();
        let target = current_cpu().unwrap_or(0);
        let _ = pin_current_thread(target);
        let _ = unpin_current_thread(&topo);
        // out-of-range CPU is rejected cleanly
        assert!(!pin_current_thread(MASK_WORDS * 64 + 1));
    }

    #[test]
    fn hugepage_advice_is_safe_on_any_slab() {
        // big enough to contain full pages after inward alignment
        let slab = vec![0u8; 1 << 20];
        let _ = advise_hugepages(slab.as_ptr(), slab.len());
        // sub-page slabs are skipped, not crashed on
        let tiny = vec![0u8; 64];
        assert!(!advise_hugepages(tiny.as_ptr(), tiny.len()));
        // zero-length range
        assert!(!advise_hugepages(slab.as_ptr(), 0));
    }

    #[test]
    fn gauges_publish_node_and_cpu_counts() {
        let topo = Topology::fallback();
        topo.publish_gauges();
        let text = metrics::global().render_prometheus();
        assert!(text.contains("skipper_topology_nodes"), "{text}");
        assert!(text.contains("skipper_topology_cpus"), "{text}");
    }
}
