//! Persistent shard-worker pool: standing threads with per-worker run
//! queues, parked between epochs, woken by their queue's condvar doorbell.
//!
//! ## Why a standing pool
//!
//! The sharded dynamic engine used to fork one scoped thread per shard per
//! epoch (`std::thread::scope` inside `apply_epoch`). For large epochs the
//! spawn cost vanishes into the mutate work, but the service's steady state
//! is the opposite regime: many *small* coalesced epochs, where forking P
//! threads can cost more than the adjacency edits they perform. The paper's
//! whole argument is about removing synchronization overhead from the inner
//! loop (APRAM relaxation, single-pass reservation); re-paying a thread
//! spawn per epoch at the orchestration layer squanders that. A
//! [`WorkerPool`] keeps one thread per shard alive for the engine's
//! lifetime:
//!
//! * **per-worker run queues** — each worker owns a
//!   [`BoundedQueue`](crate::par::pump::BoundedQueue) of boxed jobs, so
//!   shard `i`'s work always lands on worker `i` (stable shard→thread
//!   affinity, the precondition for NUMA pinning later);
//! * **parked workers, doorbell wakeups** — an idle worker blocks in
//!   `pop()` on its queue's condvar; submitting a job is one lock + one
//!   `notify_one`, the same doorbell discipline the service's
//!   [`ShardedQueue`](crate::service::ShardedQueue) uses;
//! * **epoch barrier via a shared countdown** — dispatchers pair each batch
//!   of jobs with a [`Countdown`]; every job arrives on completion (via a
//!   drop guard, so even a panicking job releases the barrier) and the
//!   dispatcher's `wait()` is the phase barrier that `run_threads_collect`'s
//!   join used to provide.
//!
//! Jobs are `'static` closures: callers move `Arc`s of their shared state
//! (and any per-shard owned data) into the job and get results back through
//! slots they also share — see
//! [`ShardedDynamicMatcher`](crate::dynamic::ShardedDynamicMatcher) for the
//! canonical dispatch pattern. A worker that observes its queue closed
//! exits; dropping the pool closes every queue and joins every thread.

use super::pump::BoundedQueue;
use super::topology::{self, CpuSlot, PinPolicy, Topology};
use crate::obs::{metrics, trace};
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// A unit of work submitted to one worker.
pub type Job = Box<dyn FnOnce() + Send + 'static>;

/// A queued job plus its submission timestamp, so the worker that pops it
/// can report the spawn-to-run delay (how long work sat in the run queue —
/// the pool's replacement for the old per-epoch thread-spawn overhead).
struct Submitted {
    job: Job,
    queued_at: Instant,
}

/// Per-worker run-queue depth. Dispatch is phase-at-a-time (mutate, then
/// repair), so one slot would suffice; a second gives slack for a caller
/// that pre-queues the next phase.
const RUN_QUEUE_DEPTH: usize = 2;

/// A fixed-size pool of named, persistent worker threads with per-worker
/// run queues.
///
/// Workers park on their queue's condvar when idle and are woken by the
/// push that submits a job — no spinning, no per-epoch thread spawn. A job
/// that panics is contained to the job (the worker catches the unwind and
/// keeps serving); callers that wait on a [`Countdown`] observe the panic
/// as a missing result and surface it on their own thread.
pub struct WorkerPool {
    queues: Vec<Arc<BoundedQueue<Submitted>>>,
    handles: Vec<JoinHandle<()>>,
    queue_depth: Arc<metrics::Gauge>,
    /// Per-worker placement under the pool's [`PinPolicy`] (`None` entries
    /// for unpinned workers). Workers whose `sched_setaffinity` is refused
    /// keep their planned slot here — the plan is intent, the
    /// `pinned` counter is outcome.
    plan: Vec<Option<CpuSlot>>,
    pin: PinPolicy,
    /// Workers whose pin syscall actually succeeded.
    pinned: Arc<AtomicUsize>,
    pinned_gauge: Arc<metrics::Gauge>,
}

impl WorkerPool {
    /// Spawn `workers` (clamped ≥ 1) parked threads, each with its own run
    /// queue. Threads are named `skipper-pool-<i>` for debuggability.
    /// Unpinned ([`PinPolicy::None`]) — the historical default.
    pub fn new(workers: usize) -> Self {
        Self::with_pin(workers, PinPolicy::None)
    }

    /// Like [`new`](Self::new) with worker→core pinning: the topology is
    /// discovered (single synthetic node when sysfs is absent), `pin`
    /// plans a core per worker, and each worker pins *itself* on its own
    /// thread before serving jobs — so everything the worker subsequently
    /// allocates and first-touches (shard arenas, `partner[]` stripes)
    /// lands on that core's NUMA node. A refused `sched_setaffinity`
    /// leaves the worker floating; placement is advice, never an error.
    pub fn with_pin(workers: usize, pin: PinPolicy) -> Self {
        let plan = if pin == PinPolicy::None {
            vec![None; workers.max(1)]
        } else {
            let topo = Topology::discover();
            topo.publish_gauges();
            topo.plan(pin, workers.max(1))
        };
        let reg = metrics::global();
        let queue_depth = reg.gauge(
            "skipper_pool_queue_depth",
            "Jobs submitted to the worker pool and not yet started",
        );
        let spawn_delay = reg.histogram_secs(
            "skipper_pool_spawn_delay_seconds",
            "Delay between job submission and a worker starting it",
        );
        let jobs_run = reg.counter(
            "skipper_pool_jobs_run_total",
            "Jobs executed by the worker pool",
        );
        let pinned_gauge = reg.gauge(
            "skipper_pinned_workers",
            "Pool workers currently pinned to a core (0 under --pin none)",
        );
        let pinned = Arc::new(AtomicUsize::new(0));
        let queues: Vec<Arc<BoundedQueue<Submitted>>> = (0..workers.max(1))
            .map(|_| Arc::new(BoundedQueue::new(RUN_QUEUE_DEPTH)))
            .collect();
        let handles = queues
            .iter()
            .enumerate()
            .map(|(i, q)| {
                let q = Arc::clone(q);
                let depth = Arc::clone(&queue_depth);
                let delay = Arc::clone(&spawn_delay);
                let jobs = Arc::clone(&jobs_run);
                let slot = plan[i];
                let pinned = Arc::clone(&pinned);
                let pinned_gauge = Arc::clone(&pinned_gauge);
                std::thread::Builder::new()
                    .name(format!("skipper-pool-{i}"))
                    .spawn(move || {
                        // Pin before serving anything: the first jobs this
                        // worker runs are the engine's first-touch arena
                        // initializers, which must execute on the target
                        // core for their pages to land on its node.
                        if let Some(CpuSlot { cpu, node }) = slot {
                            if topology::pin_current_thread(cpu) {
                                pinned.fetch_add(1, Ordering::Relaxed);
                                pinned_gauge.inc(1);
                                let reg = metrics::global();
                                let labels =
                                    vec![("worker".to_string(), i.to_string())];
                                reg.gauge_with(
                                    "skipper_worker_core",
                                    "Core each pinned pool worker runs on",
                                    labels.clone(),
                                )
                                .set(cpu as u64);
                                reg.gauge_with(
                                    "skipper_worker_node",
                                    "NUMA node each pinned pool worker runs on",
                                    labels,
                                )
                                .set(node as u64);
                            }
                        }
                        loop {
                            let popped = {
                                // idle time parked on the queue condvar
                                let _park = trace::span("pool_park", "pool", i as u64);
                                q.pop()
                            };
                            let Some(sub) = popped else { break };
                            depth.dec(1);
                            delay.record_duration(sub.queued_at.elapsed());
                            jobs.inc();
                            let _run = trace::span("pool_run", "pool", i as u64);
                            // Contain job panics to the job: the worker must
                            // survive to serve the next epoch, and the
                            // dispatcher's countdown guard (dropped during
                            // the unwind) releases the barrier so the
                            // coordinator can report the failure. The
                            // payload is surfaced here — the dispatcher only
                            // knows *that* shard i died, not why.
                            if let Err(payload) =
                                std::panic::catch_unwind(AssertUnwindSafe(sub.job))
                            {
                                let msg = payload
                                    .downcast_ref::<&str>()
                                    .map(|s| s.to_string())
                                    .or_else(|| payload.downcast_ref::<String>().cloned())
                                    .unwrap_or_else(|| "<non-string panic>".into());
                                eprintln!(
                                    "{}: job panicked: {msg}",
                                    std::thread::current().name().unwrap_or("skipper-pool")
                                );
                            }
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        Self { queues, handles, queue_depth, plan, pin, pinned, pinned_gauge }
    }

    /// The pin policy this pool was built with.
    pub fn pin_policy(&self) -> PinPolicy {
        self.pin
    }

    /// Worker `i`'s planned placement (`None` when unpinned or out of
    /// range). This is the *plan*; a refused syscall leaves the worker
    /// floating without clearing its slot.
    pub fn worker_slot(&self, i: usize) -> Option<CpuSlot> {
        self.plan.get(i).copied().flatten()
    }

    /// Workers whose pin syscall actually succeeded so far.
    pub fn pinned_workers(&self) -> usize {
        self.pinned.load(Ordering::Relaxed)
    }

    /// Number of workers in the pool.
    pub fn workers(&self) -> usize {
        self.queues.len()
    }

    /// Submit `job` to worker `worker % workers()`. Blocks only when that
    /// worker's run queue is full (a small fixed depth); panics if the pool
    /// is shutting down, which cannot happen while the caller holds a
    /// reference to it.
    pub fn submit(&self, worker: usize, job: impl FnOnce() + Send + 'static) {
        let q = &self.queues[worker % self.queues.len()];
        self.queue_depth.inc(1);
        let sub = Submitted { job: Box::new(job), queued_at: Instant::now() };
        if q.push(sub).is_err() {
            self.queue_depth.dec(1);
            panic!("submit to a shut-down worker pool");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for q in &self.queues {
            q.close();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        // the gauge tracks *currently* pinned workers across live pools
        self.pinned_gauge.dec(self.pinned.load(Ordering::Relaxed) as u64);
    }
}

/// A one-shot countdown barrier: `new(n)`, `n` calls to [`arrive`]
/// (typically one per pool job, via [`ArriveOnDrop`]), and [`wait`] blocks
/// until all have arrived.
///
/// [`arrive`]: Countdown::arrive
/// [`wait`]: Countdown::wait
pub struct Countdown {
    remaining: Mutex<usize>,
    zero: Condvar,
}

impl Countdown {
    /// A barrier expecting `n` arrivals.
    pub fn new(n: usize) -> Self {
        Self { remaining: Mutex::new(n), zero: Condvar::new() }
    }

    /// Record one arrival; wakes waiters when the count reaches zero.
    /// Saturating (never panics), so it is safe to call from a drop guard
    /// running during a panic unwind.
    pub fn arrive(&self) {
        let mut r = self.remaining.lock().unwrap();
        *r = r.saturating_sub(1);
        let done = *r == 0;
        drop(r);
        if done {
            self.zero.notify_all();
        }
    }

    /// Block until every expected arrival has happened.
    pub fn wait(&self) {
        let mut r = self.remaining.lock().unwrap();
        while *r > 0 {
            r = self.zero.wait(r).unwrap();
        }
    }
}

/// Calls [`Countdown::arrive`] when dropped. Jobs hold one so the barrier
/// is released even when the job panics — the dispatcher then finds the
/// job's result slot empty and reports the failure from its own thread
/// instead of hanging.
pub struct ArriveOnDrop(pub Arc<Countdown>);

impl Drop for ArriveOnDrop {
    fn drop(&mut self) {
        self.0.arrive();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn jobs_run_and_barrier_releases() {
        let pool = WorkerPool::new(4);
        let hits = Arc::new(AtomicUsize::new(0));
        let done = Arc::new(Countdown::new(8));
        for i in 0..8 {
            let hits = Arc::clone(&hits);
            let done = Arc::clone(&done);
            pool.submit(i, move || {
                let _g = ArriveOnDrop(done);
                hits.fetch_add(1, Ordering::Relaxed);
            });
        }
        done.wait();
        assert_eq!(hits.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn workers_persist_across_epochs() {
        // many rounds through the same pool: every round's jobs complete,
        // proving workers park and wake instead of exiting
        let pool = WorkerPool::new(2);
        let total = Arc::new(AtomicUsize::new(0));
        for round in 0..50 {
            let done = Arc::new(Countdown::new(2));
            for w in 0..2 {
                let total = Arc::clone(&total);
                let done = Arc::clone(&done);
                pool.submit(w, move || {
                    let _g = ArriveOnDrop(done);
                    total.fetch_add(round + w, Ordering::Relaxed);
                });
            }
            done.wait();
        }
        let expect: usize = (0..50).map(|r| r + r + 1).sum();
        assert_eq!(total.load(Ordering::Relaxed), expect);
    }

    #[test]
    fn shard_affinity_lands_on_the_submitted_worker() {
        let pool = WorkerPool::new(3);
        let done = Arc::new(Countdown::new(3));
        let names = Arc::new(Mutex::new(Vec::new()));
        for w in 0..3 {
            let done = Arc::clone(&done);
            let names = Arc::clone(&names);
            pool.submit(w, move || {
                let _g = ArriveOnDrop(done);
                let name = std::thread::current().name().unwrap_or("?").to_string();
                names.lock().unwrap().push((w, name));
            });
        }
        done.wait();
        for (w, name) in names.lock().unwrap().iter() {
            assert_eq!(name, &format!("skipper-pool-{w}"), "job {w} ran on {name}");
        }
    }

    #[test]
    fn panicking_job_releases_barrier_and_worker_survives() {
        let pool = WorkerPool::new(1);
        let done = Arc::new(Countdown::new(1));
        {
            let done = Arc::clone(&done);
            pool.submit(0, move || {
                let _g = ArriveOnDrop(done);
                panic!("job panic must not kill the worker");
            });
        }
        done.wait(); // released by the drop guard during the unwind
        // the same worker still serves jobs
        let done2 = Arc::new(Countdown::new(1));
        let ok = Arc::new(AtomicUsize::new(0));
        {
            let done2 = Arc::clone(&done2);
            let ok = Arc::clone(&ok);
            pool.submit(0, move || {
                let _g = ArriveOnDrop(done2);
                ok.store(1, Ordering::Relaxed);
            });
        }
        done2.wait();
        assert_eq!(ok.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn drop_joins_all_workers() {
        let pool = WorkerPool::new(4);
        let done = Arc::new(Countdown::new(4));
        for w in 0..4 {
            let done = Arc::clone(&done);
            pool.submit(w, move || {
                let _g = ArriveOnDrop(done);
            });
        }
        done.wait();
        drop(pool); // must not hang: queues close, workers exit, joins return
    }

    #[test]
    fn countdown_of_zero_never_blocks() {
        let c = Countdown::new(0);
        c.wait();
        c.arrive(); // saturating: no panic
        c.wait();
    }

    #[test]
    fn unpinned_pool_has_no_placement() {
        let pool = WorkerPool::new(3);
        assert_eq!(pool.pin_policy(), PinPolicy::None);
        assert!((0..3).all(|i| pool.worker_slot(i).is_none()));
        assert_eq!(pool.pinned_workers(), 0);
    }

    #[test]
    fn pinned_pool_serves_jobs_and_reports_placement() {
        // compact always yields a plan (discovery falls back to one node
        // covering every CPU); whether the pin syscall succeeds is
        // host-dependent, so only the bookkeeping is asserted
        let pool = WorkerPool::with_pin(2, PinPolicy::Compact);
        assert_eq!(pool.pin_policy(), PinPolicy::Compact);
        assert!(pool.worker_slot(0).is_some());
        assert!(pool.worker_slot(1).is_some());
        assert!(pool.worker_slot(99).is_none());
        let hits = Arc::new(AtomicUsize::new(0));
        let done = Arc::new(Countdown::new(2));
        for w in 0..2 {
            let hits = Arc::clone(&hits);
            let done = Arc::clone(&done);
            pool.submit(w, move || {
                let _g = ArriveOnDrop(done);
                hits.fetch_add(1, Ordering::Relaxed);
            });
        }
        done.wait();
        assert_eq!(hits.load(Ordering::Relaxed), 2);
        // workers attempt the pin before serving their first job
        assert!(pool.pinned_workers() <= 2);
    }

    #[test]
    fn spread_pool_round_robins_nodes_in_plan() {
        let pool = WorkerPool::with_pin(4, PinPolicy::Spread);
        // on a single-node host every slot lands on node 0; on a multi-node
        // host consecutive workers alternate nodes — both are covered by
        // checking the plan matches the topology's own answer
        let topo = Topology::discover();
        let want = topo.plan(PinPolicy::Spread, 4);
        for (i, slot) in want.iter().enumerate() {
            assert_eq!(pool.worker_slot(i), *slot);
        }
    }
}
