//! Scoped thread parallelism and the paper's block scheduler.
//!
//! The sandbox this reproduction runs in has a single physical core, so
//! `std::thread`-based runs validate *correctness* under preemptive
//! interleaving, while the [`crate::apram`] simulator reproduces the
//! *t-thread performance shape* (see DESIGN.md §3).

pub mod pool;
pub mod pump;
pub mod scheduler;
pub mod topology;

/// Run `f(tid)` on `t` scoped threads and join. `f` observes its thread id.
pub fn run_threads<F>(t: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    assert!(t >= 1);
    if t == 1 {
        f(0);
        return;
    }
    std::thread::scope(|s| {
        for tid in 0..t {
            let f = &f;
            s.spawn(move || f(tid));
        }
    });
}

/// Run `f(tid)` on `t` scoped threads, collecting each thread's return value
/// in tid order.
pub fn run_threads_collect<F, R>(t: usize, f: F) -> Vec<R>
where
    F: Fn(usize) -> R + Sync,
    R: Send,
{
    assert!(t >= 1);
    if t == 1 {
        return vec![f(0)];
    }
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..t)
            .map(|tid| {
                let f = &f;
                s.spawn(move || f(tid))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

/// Parallel for over `0..n`, contiguous chunks, `f(tid, start, end)`.
pub fn par_for_range<F>(t: usize, n: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let chunk = n.div_ceil(t.max(1));
    run_threads(t, |tid| {
        let start = (tid * chunk).min(n);
        let end = ((tid + 1) * chunk).min(n);
        if start < end {
            f(tid, start, end);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn run_threads_covers_all_tids() {
        let seen = AtomicUsize::new(0);
        run_threads(4, |tid| {
            seen.fetch_or(1 << tid, Ordering::Relaxed);
        });
        assert_eq!(seen.load(Ordering::Relaxed), 0b1111);
    }

    #[test]
    fn collect_preserves_order() {
        let v = run_threads_collect(5, |tid| tid * 10);
        assert_eq!(v, vec![0, 10, 20, 30, 40]);
    }

    #[test]
    fn par_for_range_partitions_exactly() {
        let sum = AtomicUsize::new(0);
        let count = AtomicUsize::new(0);
        par_for_range(3, 100, |_tid, s, e| {
            for i in s..e {
                sum.fetch_add(i, Ordering::Relaxed);
                count.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert_eq!(count.load(Ordering::Relaxed), 100);
        assert_eq!(sum.load(Ordering::Relaxed), 99 * 100 / 2);
    }

    #[test]
    fn par_for_range_more_threads_than_items() {
        let count = AtomicUsize::new(0);
        par_for_range(8, 3, |_t, s, e| {
            count.fetch_add(e - s, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn single_thread_runs_inline() {
        let touched = std::sync::atomic::AtomicBool::new(false);
        run_threads(1, |tid| {
            assert_eq!(tid, 0);
            touched.store(true, Ordering::Relaxed);
        });
        assert!(touched.load(Ordering::Relaxed));
    }
}
