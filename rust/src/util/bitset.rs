//! Compact bitset over `u64` words, plus an atomic variant used by the
//! instrumented SGMM (the paper notes SGMM needs a single *bit* per vertex;
//! Skipper needs a byte).

use std::sync::atomic::{AtomicU64, Ordering};

/// Plain (single-threaded) bitset.
#[derive(Clone, Debug)]
pub struct Bitset {
    words: Vec<u64>,
    len: usize,
}

impl Bitset {
    /// All-zero bitset of `len` bits.
    pub fn new(len: usize) -> Self {
        Self {
            words: vec![0; len.div_ceil(64)],
            len,
        }
    }

    #[inline]
    /// Number of bits.
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    /// True for a zero-length bitset.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    /// Read bit `i`.
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i >> 6] >> (i & 63)) & 1 == 1
    }

    #[inline]
    /// Set bit `i`.
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i >> 6] |= 1u64 << (i & 63);
    }

    #[inline]
    /// Clear bit `i`.
    pub fn clear(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i >> 6] &= !(1u64 << (i & 63));
    }

    /// Zero every bit, keeping the length.
    pub fn clear_all(&mut self) {
        self.words.fill(0);
    }

    /// Number of set bits.
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Iterate over set bit positions.
    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut w = w;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let b = w.trailing_zeros() as usize;
                    w &= w - 1;
                    Some(wi * 64 + b)
                }
            })
        })
    }
}

/// Thread-safe bitset (relaxed atomics; callers impose ordering).
pub struct AtomicBitset {
    words: Vec<AtomicU64>,
    len: usize,
}

impl AtomicBitset {
    /// All-zero atomic bitset of `len` bits.
    pub fn new(len: usize) -> Self {
        Self {
            words: (0..len.div_ceil(64)).map(|_| AtomicU64::new(0)).collect(),
            len,
        }
    }

    #[inline]
    /// Number of bits.
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    /// True for a zero-length bitset.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    /// Read bit `i` (acquire).
    pub fn get(&self, i: usize) -> bool {
        (self.words[i >> 6].load(Ordering::Acquire) >> (i & 63)) & 1 == 1
    }

    /// Atomically set bit `i`; returns `true` iff this call changed it
    /// (i.e. the caller "won" the bit).
    #[inline]
    pub fn test_and_set(&self, i: usize) -> bool {
        let mask = 1u64 << (i & 63);
        let prev = self.words[i >> 6].fetch_or(mask, Ordering::AcqRel);
        prev & mask == 0
    }

    /// Number of set bits.
    pub fn count_ones(&self) -> usize {
        self.words
            .iter()
            .map(|w| w.load(Ordering::Acquire).count_ones() as usize)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_clear() {
        let mut b = Bitset::new(130);
        assert!(!b.get(0) && !b.get(129));
        b.set(0);
        b.set(64);
        b.set(129);
        assert!(b.get(0) && b.get(64) && b.get(129) && !b.get(1));
        assert_eq!(b.count_ones(), 3);
        b.clear(64);
        assert!(!b.get(64));
        assert_eq!(b.count_ones(), 2);
    }

    #[test]
    fn iter_ones_matches_set_bits() {
        let mut b = Bitset::new(200);
        let idx = [0usize, 3, 63, 64, 65, 127, 128, 199];
        for &i in &idx {
            b.set(i);
        }
        let got: Vec<usize> = b.iter_ones().collect();
        assert_eq!(got, idx);
    }

    #[test]
    fn clear_all_resets() {
        let mut b = Bitset::new(100);
        for i in 0..100 {
            b.set(i);
        }
        b.clear_all();
        assert_eq!(b.count_ones(), 0);
    }

    #[test]
    fn atomic_test_and_set_wins_once() {
        let b = AtomicBitset::new(70);
        assert!(b.test_and_set(69));
        assert!(!b.test_and_set(69));
        assert!(b.get(69));
        assert_eq!(b.count_ones(), 1);
    }

    #[test]
    fn atomic_concurrent_single_winner() {
        let b = std::sync::Arc::new(AtomicBitset::new(64));
        let mut handles = vec![];
        let wins = std::sync::Arc::new(AtomicU64::new(0));
        for _ in 0..8 {
            let b = b.clone();
            let wins = wins.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..64 {
                    if b.test_and_set(i) {
                        wins.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // each of the 64 bits has exactly one winner
        assert_eq!(wins.load(Ordering::Relaxed), 64);
    }
}
