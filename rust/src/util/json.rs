//! Minimal JSON tree, parser, and **canonical** renderer (serde is
//! unavailable offline).
//!
//! Built for the `BENCH_*.json` perf-trajectory registry
//! ([`crate::coordinator::registry`]): records are committed to git, so the
//! on-disk form must be deterministic — objects render with keys in sorted
//! order (they are stored in a [`BTreeMap`]), arrays in insertion order,
//! numbers in shortest-roundtrip form — and re-rendering a parsed file is
//! byte-identical. This keeps registry diffs reviewable and lets a config
//! hash be computed from the rendered bytes.
//!
//! Supported surface: objects, arrays, strings (with `\uXXXX` escapes),
//! finite numbers, booleans, `null`. Non-finite floats render as `null`,
//! like every mainstream encoder.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (integers are exact up to 2^53).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object; [`BTreeMap`] keeps keys sorted → canonical rendering.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// An empty object.
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert `key` into an object (panics on non-objects — builder misuse,
    /// not data error).
    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value);
            }
            other => panic!("Json::set on non-object {other:?}"),
        }
        self
    }

    /// Member of an object, if this is an object that has it.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Numeric payload as an unsigned integer (must be whole and in range).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if x.fract() == 0.0 && (0.0..=9.007_199_254_740_992e15).contains(x) => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    /// Array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object members, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Canonical compact rendering (no whitespace, sorted keys).
    pub fn render_compact(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out, None, 0);
        out
    }

    /// Canonical pretty rendering (2-space indent, sorted keys, trailing
    /// newline) — the committed-file form.
    pub fn render_pretty(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn render_into(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in, colon) = match indent {
            Some(w) => ("\n", " ".repeat(w * depth), " ".repeat(w * (depth + 1)), ": "),
            None => ("", String::new(), String::new(), ":"),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => render_number(out, *x),
            Json::Str(s) => render_string(out, s),
            Json::Arr(v) if v.is_empty() => out.push_str("[]"),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    item.render_into(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) if m.is_empty() => out.push_str("{}"),
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    render_string(out, k);
                    out.push_str(colon);
                    v.render_into(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}

impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}

impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}

fn render_number(out: &mut String, x: f64) {
    if !x.is_finite() {
        out.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 9.007_199_254_740_992e15 {
        // whole numbers render without a fraction: counts stay diff-stable
        let _ = write!(out, "{}", x as i64);
    } else {
        // Rust's f64 Display is shortest-roundtrip — canonical by itself
        let _ = write!(out, "{x}");
    }
}

fn render_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document (must consume the whole input).
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while matches!(self.bytes.get(self.pos), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", b as char, self.pos))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(format!("unexpected input at byte {}", self.pos)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or("truncated \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| format!("bad \\u escape {hex:?}"))?;
                            self.pos += 4;
                            // surrogate pairs are out of scope for registry
                            // files; map lone surrogates to the replacement
                            // character rather than erroring
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        other => return Err(format!("bad escape \\{}", other as char)),
                    }
                }
                Some(_) => {
                    // copy one UTF-8 scalar (input is &str, so boundaries
                    // are valid)
                    let rest = &self.bytes[self.pos..];
                    let tail = std::str::from_utf8(rest).map_err(|e| e.to_string())?;
                    let c = tail.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("invalid number {text:?} at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrips_a_nested_document() {
        let text = r#"{"b":[1,2.5,-3],"a":{"x":true,"y":null,"z":"hi\n\"q\""}}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().get("x"), Some(&Json::Bool(true)));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        // canonical: keys sorted regardless of input order
        assert_eq!(
            v.render_compact(),
            r#"{"a":{"x":true,"y":null,"z":"hi\n\"q\""},"b":[1,2.5,-3]}"#
        );
    }

    #[test]
    fn rendering_is_a_fixed_point() {
        let mut doc = Json::obj();
        doc.set("zeta", Json::from(3u64))
            .set("alpha", Json::Arr(vec![Json::from("a"), Json::from(0.125f64)]))
            .set("nested", {
                let mut o = Json::obj();
                o.set("k", Json::Null);
                o
            });
        let pretty = doc.render_pretty();
        assert_eq!(parse(&pretty).unwrap().render_pretty(), pretty);
        assert!(pretty.ends_with('\n'));
        // sorted: alpha before nested before zeta
        let (a, z) = (pretty.find("alpha").unwrap(), pretty.find("zeta").unwrap());
        assert!(a < z);
    }

    #[test]
    fn whole_numbers_render_without_fraction() {
        assert_eq!(Json::from(1_000_000u64).render_compact(), "1000000");
        assert_eq!(Json::Num(0.5).render_compact(), "0.5");
        assert_eq!(Json::Num(f64::NAN).render_compact(), "null");
    }

    #[test]
    fn u64_accessor_rejects_fractions_and_negatives() {
        assert_eq!(Json::Num(42.0).as_u64(), Some(42));
        assert_eq!(Json::Num(42.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
    }

    #[test]
    fn parse_errors_are_reported() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("123 junk").is_err());
        assert!(parse(r#"{"k" 1}"#).is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn unicode_escapes_decode() {
        let v = parse(r#""café \t ok""#).unwrap();
        assert_eq!(v.as_str(), Some("café \t ok"));
    }
}
