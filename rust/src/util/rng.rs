//! Deterministic pseudo-random number generators.
//!
//! `SplitMix64` seeds everything; `Xoshiro256pp` is the workhorse stream
//! generator (same generator family LaganLighter-style graph tooling uses).
//! Both are tiny, copyable, and reproducible across runs — a requirement for
//! the experiment harness, which reports seeds next to every measurement.

/// SplitMix64: fast, full-period 2^64 stream; the standard seeder.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Generator starting from `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    /// Next 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — 256-bit state, excellent statistical quality, jumpable.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 as recommended by the xoshiro authors.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    /// Next 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift reduction
    /// (biased by < 2^-64; fine for workload generation).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform `f64` in `[0, 1)` using the top 53 bits.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, bound)` as usize.
    #[inline]
    pub fn next_usize(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.next_usize(i + 1);
            slice.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(7);
        let mut b = SplitMix64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_differs_by_seed() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn xoshiro_bound_respected() {
        let mut r = Xoshiro256pp::new(3);
        for bound in [1u64, 2, 3, 10, 1000, u32::MAX as u64] {
            for _ in 0..200 {
                assert!(r.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn xoshiro_f64_in_unit_interval() {
        let mut r = Xoshiro256pp::new(9);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn xoshiro_roughly_uniform() {
        let mut r = Xoshiro256pp::new(11);
        let mut buckets = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            buckets[r.next_usize(10)] += 1;
        }
        for &b in &buckets {
            // each bucket should hold ~10_000; allow +-10%
            assert!((9000..=11000).contains(&b), "bucket {b}");
        }
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = Xoshiro256pp::new(5);
        let p = r.permutation(257);
        let mut seen = vec![false; 257];
        for &x in &p {
            assert!(!seen[x as usize]);
            seen[x as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_preserves_multiset() {
        let mut r = Xoshiro256pp::new(17);
        let mut v: Vec<u32> = (0..100).map(|i| i % 7).collect();
        let mut sorted_before = v.clone();
        sorted_before.sort_unstable();
        r.shuffle(&mut v);
        v.sort_unstable();
        assert_eq!(v, sorted_before);
    }
}
