//! TOML-subset parser for experiment configs (the `toml`/`serde` crates are
//! unavailable offline).
//!
//! Supported: `[section]` and `[[array-of-tables]]` headers, `key = value`
//! with string / integer / float / boolean / flat string-or-number arrays,
//! `#` comments, blank lines. This covers everything the coordinator's
//! config files need (see `configs/*.toml`).

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
/// A parsed TOML-subset value.
pub enum Value {
    /// Quoted string.
    Str(String),
    /// Integer literal.
    Int(i64),
    /// Float literal.
    Float(f64),
    /// `true`/`false`.
    Bool(bool),
    /// Flat array of values.
    Array(Vec<Value>),
}

impl Value {
    /// String contents, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    /// Integer contents, if this is an integer.
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// Float contents (integers coerce).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    /// Boolean contents, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// Array contents, if this is an array.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// One `key = value` table.
pub type TableData = BTreeMap<String, Value>;

/// Parsed document: the root table, named sections, and arrays of tables.
#[derive(Debug, Default, Clone)]
pub struct Document {
    /// Keys above the first section header.
    pub root: TableData,
    /// `[section]` tables by name.
    pub sections: BTreeMap<String, TableData>,
    /// `[[array-of-tables]]` entries by name.
    pub table_arrays: BTreeMap<String, Vec<TableData>>,
}

impl Document {
    /// Parse a TOML-subset document.
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut doc = Document::default();
        enum Target {
            Root,
            Section(String),
            ArrayItem(String),
        }
        let mut target = Target::Root;
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
                let name = name.trim().to_string();
                doc.table_arrays.entry(name.clone()).or_default().push(TableData::new());
                target = Target::ArrayItem(name);
            } else if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                let name = name.trim().to_string();
                doc.sections.entry(name.clone()).or_default();
                target = Target::Section(name);
            } else if let Some((k, v)) = line.split_once('=') {
                let key = k.trim().to_string();
                let value = parse_value(v.trim())
                    .map_err(|e| format!("line {}: {}", lineno + 1, e))?;
                let table = match &target {
                    Target::Root => &mut doc.root,
                    Target::Section(s) => doc.sections.get_mut(s).unwrap(),
                    Target::ArrayItem(s) => doc.table_arrays.get_mut(s).unwrap().last_mut().unwrap(),
                };
                table.insert(key, value);
            } else {
                return Err(format!("line {}: cannot parse {:?}", lineno + 1, raw));
            }
        }
        Ok(doc)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if let Some(body) = s.strip_prefix('"') {
        let body = body.strip_suffix('"').ok_or_else(|| format!("unterminated string: {s:?}"))?;
        return Ok(Value::Str(body.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body.strip_suffix(']').ok_or_else(|| format!("unterminated array: {s:?}"))?;
        let body = body.trim();
        if body.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items: Result<Vec<Value>, String> =
            split_top_level(body).iter().map(|item| parse_value(item.trim())).collect();
        return Ok(Value::Array(items?));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

/// Split an array body on commas not inside strings (no nested arrays).
fn split_top_level(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            ',' if !in_str => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_document() {
        let doc = Document::parse(
            r#"
            # experiment config
            name = "suite"   # trailing comment
            threads = 64
            frac = 0.5
            verify = true
            sizes = [1, 2, 3]

            [output]
            dir = "reports"

            [[dataset]]
            name = "g500"
            scale = 20

            [[dataset]]
            name = "twitter"
            scale = 18
            "#,
        )
        .unwrap();
        assert_eq!(doc.root["name"].as_str(), Some("suite"));
        assert_eq!(doc.root["threads"].as_int(), Some(64));
        assert_eq!(doc.root["frac"].as_float(), Some(0.5));
        assert_eq!(doc.root["verify"].as_bool(), Some(true));
        assert_eq!(doc.root["sizes"].as_array().unwrap().len(), 3);
        assert_eq!(doc.sections["output"]["dir"].as_str(), Some("reports"));
        let ds = &doc.table_arrays["dataset"];
        assert_eq!(ds.len(), 2);
        assert_eq!(ds[0]["name"].as_str(), Some("g500"));
        assert_eq!(ds[1]["scale"].as_int(), Some(18));
    }

    #[test]
    fn string_with_hash_not_comment() {
        let doc = Document::parse(r##"tag = "a#b""##).unwrap();
        assert_eq!(doc.root["tag"].as_str(), Some("a#b"));
    }

    #[test]
    fn string_array() {
        let doc = Document::parse(r#"names = ["a", "b,c", "d"]"#).unwrap();
        let arr = doc.root["names"].as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].as_str(), Some("b,c"));
    }

    #[test]
    fn bad_line_is_error() {
        assert!(Document::parse("not a kv line").is_err());
        assert!(Document::parse("x = @nope").is_err());
        assert!(Document::parse("s = \"unterminated").is_err());
    }

    #[test]
    fn int_vs_float() {
        let doc = Document::parse("a = 3\nb = 3.5").unwrap();
        assert_eq!(doc.root["a"].as_int(), Some(3));
        assert_eq!(doc.root["a"].as_float(), Some(3.0));
        assert_eq!(doc.root["b"].as_float(), Some(3.5));
        assert_eq!(doc.root["b"].as_int(), None);
    }
}
