//! Shared utilities: RNG, bitset, statistics, CLI parsing, a mini
//! property-testing framework ([`qcheck`]) and a bench harness
//! ([`benchlib`]). These substrates replace crates that are unavailable in
//! the offline build environment (rand, criterion, proptest, clap).

pub mod benchlib;
pub mod bitset;
pub mod cli;
pub mod json;
pub mod qcheck;
pub mod rng;
pub mod stats;
pub mod tomlite;
