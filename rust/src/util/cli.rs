//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. The main binary defines subcommands on top of this.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
/// Parsed command line: positionals, `--key value` options, bare flags.
pub struct Args {
    /// Positional arguments, in order.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare flags that were present.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    /// `known_flags` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, known_flags: &[&str]) -> Result<Self, String> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if body.is_empty() {
                    // "--" terminator: rest is positional
                    out.positional.extend(it.by_ref());
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&body) {
                    out.flags.push(body.to_string());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        return Err(format!("option --{body} expects a value"));
                    }
                    let v = it.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    return Err(format!("option --{body} expects a value"));
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// Was the bare flag `name` given?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// The value of option `key`, if given.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Option value with a default.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Parse option `key` into `T`, with a default when absent.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| format!("invalid value for --{key}: {s:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_mixed_args() {
        let a = Args::parse(argv("run --threads 8 --graph=rmat --verbose pos1"), &["verbose"]).unwrap();
        assert_eq!(a.positional, vec!["run", "pos1"]);
        assert_eq!(a.get("threads"), Some("8"));
        assert_eq!(a.get("graph"), Some("rmat"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn get_parse_with_default() {
        let a = Args::parse(argv("--n 42"), &[]).unwrap();
        assert_eq!(a.get_parse("n", 0usize).unwrap(), 42);
        assert_eq!(a.get_parse("missing", 7usize).unwrap(), 7);
        assert!(a.get_parse::<usize>("n", 0).is_ok());
    }

    #[test]
    fn invalid_value_is_error() {
        let a = Args::parse(argv("--n notanum"), &[]).unwrap();
        assert!(a.get_parse::<usize>("n", 0).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(argv("--key"), &[]).is_err());
        assert!(Args::parse(argv("--key --other v"), &[]).is_err());
    }

    #[test]
    fn double_dash_terminates() {
        let a = Args::parse(argv("a -- --not-an-option"), &[]).unwrap();
        assert_eq!(a.positional, vec!["a", "--not-an-option"]);
    }
}
