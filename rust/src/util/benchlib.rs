//! Bench harness (criterion is unavailable offline).
//!
//! Measures wall-clock with warmup, reports median/mean/stddev over
//! iterations, and prints table rows for the paper-figure benches. Bench
//! binaries (`rust/benches/*.rs`, `harness = false`) use this directly.

use super::stats;
use std::time::Instant;

#[derive(Clone, Debug)]
/// Iteration policy for one measurement.
pub struct BenchConfig {
    /// Untimed warmup iterations.
    pub warmup_iters: usize,
    /// Minimum timed iterations.
    pub min_iters: usize,
    /// Stop adding iterations past this wall-clock budget (seconds).
    pub max_seconds: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup_iters: 1,
            min_iters: 3,
            max_seconds: 10.0,
        }
    }
}

#[derive(Clone, Debug)]
/// Aggregated timings of one measurement.
pub struct BenchResult {
    /// Bench label.
    pub name: String,
    /// Timed iterations run.
    pub iters: usize,
    /// Median seconds per iteration.
    pub median_s: f64,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Sample standard deviation (seconds).
    pub stddev_s: f64,
    /// Fastest iteration (seconds).
    pub min_s: f64,
}

impl BenchResult {
    /// One formatted result line for bench output.
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>4} iters  median {:>10.4} s  mean {:>10.4} s  sd {:>8.4}",
            self.name, self.iters, self.median_s, self.mean_s, self.stddev_s
        )
    }
}

/// Time `f` per [`BenchConfig`]. `f` should perform one full run and return
/// a value that is consumed via `std::hint::black_box` to prevent DCE.
pub fn bench<T>(name: &str, cfg: &BenchConfig, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        std::hint::black_box(f());
    }
    let mut samples = Vec::new();
    let budget_start = Instant::now();
    while samples.len() < cfg.min_iters
        || (budget_start.elapsed().as_secs_f64() < cfg.max_seconds && samples.len() < 1000)
    {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
        if samples.len() >= cfg.min_iters && budget_start.elapsed().as_secs_f64() >= cfg.max_seconds
        {
            break;
        }
    }
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        median_s: stats::median(&samples),
        mean_s: stats::mean(&samples),
        stddev_s: stats::stddev(&samples),
        min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
    }
}

/// Simple fixed-width table printer for paper-style tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header count).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render the table with aligned fixed-width columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let cfg = BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            max_seconds: 0.2,
        };
        let r = bench("noop-ish", &cfg, || {
            let mut s = 0u64;
            for i in 0..1000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.iters >= 3);
        assert!(r.median_s >= 0.0 && r.median_s.is_finite());
        assert!(r.min_s <= r.median_s + 1e-9);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "val"]);
        t.row(&["a".into(), "1.00".into()]);
        t.row(&["long-name".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("long-name"));
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
