//! Mini property-based testing framework (proptest is unavailable offline).
//!
//! A property is a closure from a generated value to `Result<(), String>`.
//! On failure the runner performs greedy shrinking via a user-supplied
//! shrinker (halving-style candidates) and reports the minimal failing case
//! together with the seed, so every failure is reproducible.

use super::rng::Xoshiro256pp;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    /// Generated cases per property.
    pub cases: usize,
    /// Base seed (reported on failure for reproduction).
    pub seed: u64,
    /// Cap on greedy shrink iterations.
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 64,
            seed: 0xC0FFEE,
            max_shrink_steps: 200,
        }
    }
}

/// Run `prop` on `cfg.cases` values drawn from `gen`. Panics with the seed,
/// case index and (shrunk) failing input rendered via `Debug`.
pub fn check<T, G, P>(cfg: &Config, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Xoshiro256pp) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    check_shrink(cfg, &mut gen, |_| Vec::new(), &mut prop)
}

/// Like [`check`] but with a shrinker producing "smaller" candidates.
pub fn check_shrink<T, G, S, P>(cfg: &Config, gen: &mut G, shrink: S, prop: &mut P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Xoshiro256pp) -> T,
    S: Fn(&T) -> Vec<T>,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Xoshiro256pp::new(cfg.seed);
    for case in 0..cfg.cases {
        let value = gen(&mut rng);
        if let Err(mut msg) = prop(&value) {
            // Greedy shrink: repeatedly take the first failing candidate.
            let mut current = value;
            let mut steps = 0;
            'outer: while steps < cfg.max_shrink_steps {
                for cand in shrink(&current) {
                    steps += 1;
                    if let Err(m) = prop(&cand) {
                        current = cand;
                        msg = m;
                        continue 'outer;
                    }
                    if steps >= cfg.max_shrink_steps {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed (seed={:#x}, case {}/{}): {}\ninput: {:?}",
                cfg.seed, case, cfg.cases, msg, current
            );
        }
    }
}

/// Standard shrinker for `usize`-like sizes: 0, halves, and decrements.
pub fn shrink_usize(n: usize) -> Vec<usize> {
    let mut out = Vec::new();
    if n > 0 {
        out.push(0);
        if n > 2 {
            out.push(n / 2);
        }
        out.push(n - 1);
    }
    out.dedup();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check(
            &Config::default(),
            |r| r.next_below(1000),
            |&x| {
                if x < 1000 {
                    Ok(())
                } else {
                    Err(format!("{x} out of range"))
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(
            &Config { cases: 50, ..Default::default() },
            |r| r.next_below(100),
            |&x| {
                if x < 30 {
                    Ok(())
                } else {
                    Err("too big".into())
                }
            },
        );
    }

    #[test]
    fn shrinking_finds_smaller_case() {
        // Property fails for all n >= 10. Shrinker should get us to exactly 10.
        let result = std::panic::catch_unwind(|| {
            check_shrink(
                &Config { cases: 20, ..Default::default() },
                &mut |r: &mut Xoshiro256pp| 10 + r.next_usize(1000),
                |&n| shrink_usize(n),
                &mut |&n: &usize| {
                    if n < 10 {
                        Ok(())
                    } else {
                        Err("n >= 10".into())
                    }
                },
            )
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("input: 10"), "expected shrink to 10, got: {msg}");
    }

    #[test]
    fn shrink_usize_candidates() {
        assert!(shrink_usize(0).is_empty());
        assert_eq!(shrink_usize(1), vec![0]);
        assert_eq!(shrink_usize(10), vec![0, 5, 9]);
    }
}
