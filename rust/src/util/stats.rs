//! Summary statistics used by the experiment harness and bench reports:
//! geometric mean (the paper's headline aggregator), mean/stddev, medians
//! and percentiles.

/// Geometric mean of strictly-positive values. Returns `None` on empty input
/// or any non-positive value.
pub fn geomean(xs: &[f64]) -> Option<f64> {
    if xs.is_empty() || xs.iter().any(|&x| x <= 0.0 || !x.is_finite()) {
        return None;
    }
    let log_sum: f64 = xs.iter().map(|&x| x.ln()).sum();
    Some((log_sum / xs.len() as f64).exp())
}

/// Arithmetic mean (`NaN` on empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator); 0 for n < 2.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Percentile via linear interpolation on the sorted copy; `p` in [0,100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    if v.len() == 1 {
        return v[0];
    }
    let rank = p.clamp(0.0, 100.0) / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    v[lo] + (v[hi] - v[lo]) * frac
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basic() {
        let g = geomean(&[1.0, 100.0]).unwrap();
        assert!((g - 10.0).abs() < 1e-12);
        let g = geomean(&[2.0, 8.0]).unwrap();
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_rejects_nonpositive_and_empty() {
        assert!(geomean(&[]).is_none());
        assert!(geomean(&[1.0, 0.0]).is_none());
        assert!(geomean(&[1.0, -3.0]).is_none());
    }

    #[test]
    fn mean_stddev_known_values() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        // sample stddev of this classic set is ~2.138
        assert!((stddev(&xs) - 2.13808993).abs() < 1e-6);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn median_odd() {
        assert!((median(&[3.0, 1.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn stddev_singleton_is_zero() {
        assert_eq!(stddev(&[5.0]), 0.0);
    }
}
