//! JIT-conflict telemetry (paper Table II): per-edge conflict counts
//! aggregated into max / total / #edges / average and the bucketed
//! distribution the table reports.

/// Bucket upper bounds matching Table II's columns:
/// 1, 2, 3–4, 5–8, 9–16, 17–32, 33–64, 65–128, 129–256, >256.
pub const BUCKET_LABELS: [&str; 10] =
    ["1", "2", "3-4", "5-8", "9-16", "17-32", "33-64", "65-128", "129-256", ">256"];

#[derive(Default, Clone, Debug, PartialEq, Eq)]
/// Aggregated per-edge JIT-conflict statistics (Table II’s columns).
pub struct ConflictStats {
    /// Largest conflict count observed on a single edge.
    pub max_per_edge: u64,
    /// Total conflicts across all edges.
    pub total: u64,
    /// Edges that experienced at least one conflict.
    pub edges_with_conflicts: u64,
    /// Histogram over [`BUCKET_LABELS`].
    pub buckets: [u64; 10],
}

/// Bucket index for a per-edge conflict count `c >= 1`.
pub fn bucket_index(c: u64) -> usize {
    match c {
        1 => 0,
        2 => 1,
        3..=4 => 2,
        5..=8 => 3,
        9..=16 => 4,
        17..=32 => 5,
        33..=64 => 6,
        65..=128 => 7,
        129..=256 => 8,
        _ => 9,
    }
}

impl ConflictStats {
    /// Record the conflict count observed while processing one edge.
    /// Zero-conflict edges are not recorded (Table II counts only edges
    /// that experienced conflicts).
    pub fn record_edge(&mut self, conflicts: u64) {
        if conflicts == 0 {
            return;
        }
        self.total += conflicts;
        self.edges_with_conflicts += 1;
        self.max_per_edge = self.max_per_edge.max(conflicts);
        self.buckets[bucket_index(conflicts)] += 1;
    }

    /// Average conflicts per conflicting edge (Table II column 6).
    pub fn avg_per_conflicting_edge(&self) -> f64 {
        if self.edges_with_conflicts == 0 {
            0.0
        } else {
            self.total as f64 / self.edges_with_conflicts as f64
        }
    }

    /// Accumulate another thread’s statistics into this one.
    pub fn merge(&mut self, other: &ConflictStats) {
        self.max_per_edge = self.max_per_edge.max(other.max_per_edge);
        self.total += other.total;
        self.edges_with_conflicts += other.edges_with_conflicts;
        for i in 0..10 {
            self.buckets[i] += other.buckets[i];
        }
    }

    /// Render a Table II-style row fragment.
    pub fn table_row(&self) -> String {
        let dist: Vec<String> = self.buckets.iter().map(|b| b.to_string()).collect();
        format!(
            "max={} total={} edges={} avg={:.1} dist=[{}]",
            self.max_per_edge,
            self.total,
            self.edges_with_conflicts,
            self.avg_per_conflicting_edge(),
            dist.join(",")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 2);
        assert_eq!(bucket_index(5), 3);
        assert_eq!(bucket_index(8), 3);
        assert_eq!(bucket_index(16), 4);
        assert_eq!(bucket_index(17), 5);
        assert_eq!(bucket_index(64), 6);
        assert_eq!(bucket_index(128), 7);
        assert_eq!(bucket_index(256), 8);
        assert_eq!(bucket_index(257), 9);
        assert_eq!(bucket_index(10_000), 9);
    }

    #[test]
    fn record_and_average() {
        let mut s = ConflictStats::default();
        s.record_edge(0); // ignored
        s.record_edge(3);
        s.record_edge(1);
        s.record_edge(410);
        assert_eq!(s.total, 414);
        assert_eq!(s.edges_with_conflicts, 3);
        assert_eq!(s.max_per_edge, 410);
        assert!((s.avg_per_conflicting_edge() - 138.0).abs() < 1e-9);
        assert_eq!(s.buckets[0], 1);
        assert_eq!(s.buckets[2], 1);
        assert_eq!(s.buckets[9], 1);
    }

    #[test]
    fn merge_combines() {
        let mut a = ConflictStats::default();
        a.record_edge(2);
        let mut b = ConflictStats::default();
        b.record_edge(5);
        b.record_edge(1);
        a.merge(&b);
        assert_eq!(a.total, 8);
        assert_eq!(a.edges_with_conflicts, 3);
        assert_eq!(a.max_per_edge, 5);
    }

    #[test]
    fn empty_stats_average_zero() {
        assert_eq!(ConflictStats::default().avg_per_conflicting_edge(), 0.0);
    }
}
