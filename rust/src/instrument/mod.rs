//! Instrumentation substrate replacing the paper's PAPI hardware counters:
//!
//! * [`Probe`] — a zero-cost (when disabled) hook counting every load/store
//!   the algorithms issue against graph topology and algorithm state, at
//!   synthetic byte addresses so traces can be replayed through
//!   [`crate::cachesim`] for the L3-miss comparison (Fig 8).
//! * [`conflicts`] — JIT-conflict telemetry matching Table II's columns.

pub mod conflicts;

/// Synthetic address space: regions are spaced far apart so the cache
/// simulator never aliases them. All addresses are byte-granular.
pub mod address {
    /// CSR offsets array (8 B entries).
    pub const OFFSETS_BASE: u64 = 0x0000_0000_0000;
    /// CSR neighbors array (4 B entries).
    pub const NEIGHBORS_BASE: u64 = 0x1000_0000_0000;
    /// Per-vertex algorithm state (1 B entries — Skipper's byte, or the
    /// bit-packed SGMM status rounded to its containing byte).
    pub const STATE_BASE: u64 = 0x2000_0000_0000;
    /// Match output buffers (8 B per edge record).
    pub const MATCHES_BASE: u64 = 0x3000_0000_0000;
    /// Auxiliary arrays (EMS proposals, sample offsets, priorities, ...).
    pub const AUX_BASE: u64 = 0x4000_0000_0000;
    /// Second auxiliary region (e.g. SIDMM per-iteration offsets).
    pub const AUX2_BASE: u64 = 0x5000_0000_0000;

    #[inline(always)]
    /// Byte address of CSR offset entry `i`.
    pub fn offsets(i: u64) -> u64 {
        OFFSETS_BASE + i * 8
    }
    #[inline(always)]
    /// Byte address of CSR neighbor slot `i`.
    pub fn neighbors(i: u64) -> u64 {
        NEIGHBORS_BASE + i * 4
    }
    #[inline(always)]
    /// Byte address of vertex `v`’s state byte.
    pub fn state(v: u64) -> u64 {
        STATE_BASE + v
    }
    /// SGMM's bit-array status: byte address of the containing word.
    #[inline(always)]
    pub fn state_bit(v: u64) -> u64 {
        STATE_BASE + v / 8
    }
    #[inline(always)]
    /// Byte address of match-output record `i`.
    pub fn matches(i: u64) -> u64 {
        MATCHES_BASE + i * 8
    }
    #[inline(always)]
    /// Byte address of auxiliary entry `i`.
    pub fn aux(i: u64) -> u64 {
        AUX_BASE + i * 8
    }
    #[inline(always)]
    /// Byte address in the second auxiliary region.
    pub fn aux2(i: u64) -> u64 {
        AUX2_BASE + i * 8
    }
}

/// Memory-access hook. The no-op impl ([`NoProbe`]) compiles away entirely;
/// [`CountingProbe`] reproduces the paper's "number of load and store
/// instructions" metric; [`TracingProbe`] records addresses for cache
/// simulation.
pub trait Probe {
    /// Record one load at synthetic address `_addr`.
    #[inline(always)]
    fn load(&mut self, _addr: u64) {}
    /// Record one store at synthetic address `_addr`.
    #[inline(always)]
    fn store(&mut self, _addr: u64) {}
    /// An atomic RMW (CAS / fetch-op): one load + one store at `addr`.
    #[inline(always)]
    fn rmw(&mut self, addr: u64) {
        self.load(addr);
        self.store(addr);
    }
}

/// Disabled instrumentation — all hooks are empty and inlined away.
#[derive(Default, Clone, Copy, Debug)]
pub struct NoProbe;
impl Probe for NoProbe {}

/// Counts loads and stores (paper Figs 3 & 7).
#[derive(Default, Clone, Copy, Debug)]
pub struct CountingProbe {
    /// Counted loads.
    pub loads: u64,
    /// Counted stores.
    pub stores: u64,
}

impl Probe for CountingProbe {
    #[inline(always)]
    fn load(&mut self, _addr: u64) {
        self.loads += 1;
    }
    #[inline(always)]
    fn store(&mut self, _addr: u64) {
        self.stores += 1;
    }
}

impl CountingProbe {
    /// Loads + stores.
    pub fn total(&self) -> u64 {
        self.loads + self.stores
    }

    /// Sum per-thread probes into one total.
    pub fn merge(probes: &[CountingProbe]) -> CountingProbe {
        let mut out = CountingProbe::default();
        for p in probes {
            out.loads += p.loads;
            out.stores += p.stores;
        }
        out
    }
}

/// Records the full access trace for cache simulation (Fig 8). The store
/// flag lives in bit 63 (synthetic addresses stay far below it).
#[derive(Default, Clone, Debug)]
pub struct TracingProbe {
    /// Recorded accesses: address with the store flag in bit 63.
    pub events: Vec<u64>,
}

/// Bit 63 marks a store in [`TracingProbe::events`].
pub const TRACE_STORE_BIT: u64 = 1 << 63;

impl Probe for TracingProbe {
    #[inline(always)]
    fn load(&mut self, addr: u64) {
        self.events.push(addr);
    }
    #[inline(always)]
    fn store(&mut self, addr: u64) {
        self.events.push(addr | TRACE_STORE_BIT);
    }
}

impl TracingProbe {
    /// Iterate `(address, is_store)` events in record order.
    pub fn iter(&self) -> impl Iterator<Item = (u64, bool)> + '_ {
        self.events
            .iter()
            .map(|&e| (e & !TRACE_STORE_BIT, e & TRACE_STORE_BIT != 0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counting_probe_counts() {
        let mut p = CountingProbe::default();
        p.load(address::offsets(0));
        p.load(address::neighbors(3));
        p.store(address::state(5));
        p.rmw(address::state(6));
        assert_eq!(p.loads, 3);
        assert_eq!(p.stores, 2);
        assert_eq!(p.total(), 5);
    }

    #[test]
    fn merge_sums() {
        let a = CountingProbe { loads: 2, stores: 1 };
        let b = CountingProbe { loads: 5, stores: 7 };
        let m = CountingProbe::merge(&[a, b]);
        assert_eq!((m.loads, m.stores), (7, 8));
    }

    #[test]
    fn tracing_probe_tags_stores() {
        let mut p = TracingProbe::default();
        p.load(100);
        p.store(200);
        let ev: Vec<_> = p.iter().collect();
        assert_eq!(ev, vec![(100, false), (200, true)]);
    }

    #[test]
    fn address_regions_disjoint() {
        // a billion-entry array in one region must not reach the next region
        assert!(address::offsets(1 << 32) < address::NEIGHBORS_BASE);
        assert!(address::neighbors(1 << 33) < address::STATE_BASE);
        assert!(address::state(1 << 34) < address::MATCHES_BASE);
        assert!(address::matches(1 << 32) < address::AUX_BASE);
    }

    #[test]
    fn state_bit_packs_eight_per_byte() {
        assert_eq!(address::state_bit(0), address::state_bit(7));
        assert_ne!(address::state_bit(7), address::state_bit(8));
    }
}
