//! Vertex orderings. The paper processes graphs "using their published
//! vertex ordering" and argues (§V-B) that Skipper's performance is
//! ordering-independent thanks to the thread-dispersed locality-preserving
//! scheduler. This module provides the orderings the ordering-sensitivity
//! tests and benches sweep: natural, uniform-random, degree-sorted (both
//! directions), and BFS (locality-restoring).

use super::builder::relabel;
use super::CsrGraph;
use crate::util::rng::Xoshiro256pp;
use crate::VertexId;
use std::collections::VecDeque;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
/// A vertex (re)ordering policy for ordering-sensitivity sweeps.
pub enum Ordering {
    /// Keep IDs as generated/published.
    Natural,
    /// Uniform random permutation.
    Random,
    /// Descending degree (hubs first — the adversarial case for greedy).
    DegreeDescending,
    /// Ascending degree.
    DegreeAscending,
    /// BFS order from vertex 0 (locality-restoring; RCM-like).
    Bfs,
}

impl Ordering {
    /// Every ordering, in sweep order.
    pub const ALL: [Ordering; 5] = [
        Ordering::Natural,
        Ordering::Random,
        Ordering::DegreeDescending,
        Ordering::DegreeAscending,
        Ordering::Bfs,
    ];

    /// Short name used in tables and bench labels.
    pub fn name(&self) -> &'static str {
        match self {
            Ordering::Natural => "natural",
            Ordering::Random => "random",
            Ordering::DegreeDescending => "degree-desc",
            Ordering::DegreeAscending => "degree-asc",
            Ordering::Bfs => "bfs",
        }
    }
}

/// Compute the permutation `perm[old] = new` for the ordering.
pub fn permutation(g: &CsrGraph, ord: Ordering, seed: u64) -> Vec<VertexId> {
    let n = g.num_vertices();
    match ord {
        Ordering::Natural => (0..n as VertexId).collect(),
        Ordering::Random => {
            let mut rng = Xoshiro256pp::new(seed);
            rng.permutation(n)
        }
        Ordering::DegreeDescending | Ordering::DegreeAscending => {
            let mut by_degree: Vec<VertexId> = (0..n as VertexId).collect();
            // stable sort keeps determinism across ties
            by_degree.sort_by_key(|&v| g.degree(v));
            if ord == Ordering::DegreeDescending {
                by_degree.reverse();
            }
            // by_degree[new] = old  →  perm[old] = new
            let mut perm = vec![0 as VertexId; n];
            for (new, &old) in by_degree.iter().enumerate() {
                perm[old as usize] = new as VertexId;
            }
            perm
        }
        Ordering::Bfs => {
            let mut perm = vec![VertexId::MAX; n];
            let mut next: VertexId = 0;
            let mut queue = VecDeque::new();
            for root in 0..n as VertexId {
                if perm[root as usize] != VertexId::MAX {
                    continue;
                }
                perm[root as usize] = next;
                next += 1;
                queue.push_back(root);
                while let Some(v) = queue.pop_front() {
                    for &u in g.neighbors(v) {
                        if perm[u as usize] == VertexId::MAX {
                            perm[u as usize] = next;
                            next += 1;
                            queue.push_back(u);
                        }
                    }
                }
            }
            perm
        }
    }
}

/// Relabel a graph into the given ordering.
pub fn reorder(g: &CsrGraph, ord: Ordering, seed: u64) -> CsrGraph {
    match ord {
        Ordering::Natural => g.clone(),
        _ => relabel(g, &permutation(g, ord, seed)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{barabasi_albert, rmat, GenConfig};
    use crate::matching::{skipper::Skipper, verify, MaximalMatcher};

    fn degrees_sorted(g: &CsrGraph) -> Vec<usize> {
        let mut d: Vec<usize> = (0..g.num_vertices() as VertexId).map(|v| g.degree(v)).collect();
        d.sort_unstable();
        d
    }

    #[test]
    fn permutations_are_bijective() {
        let g = rmat::generate(&GenConfig { scale: 9, avg_degree: 6, seed: 1 });
        for ord in Ordering::ALL {
            let p = permutation(&g, ord, 7);
            let mut seen = vec![false; p.len()];
            for &x in &p {
                assert!(!seen[x as usize], "{}", ord.name());
                seen[x as usize] = true;
            }
        }
    }

    #[test]
    fn reorder_preserves_topology_invariants() {
        let g = barabasi_albert::generate(2000, 4, 3);
        let base = degrees_sorted(&g);
        for ord in Ordering::ALL {
            let g2 = reorder(&g, ord, 11);
            assert_eq!(degrees_sorted(&g2), base, "{}", ord.name());
            assert_eq!(g2.num_edge_slots(), g.num_edge_slots(), "{}", ord.name());
        }
    }

    #[test]
    fn degree_orderings_actually_sort() {
        let g = barabasi_albert::generate(1000, 4, 5);
        let gd = reorder(&g, Ordering::DegreeDescending, 0);
        // vertex 0 has the max degree after descending reorder
        assert_eq!(gd.degree(0), gd.max_degree());
        let ga = reorder(&g, Ordering::DegreeAscending, 0);
        let dmin = (0..ga.num_vertices() as u32).map(|v| ga.degree(v)).min().unwrap();
        assert_eq!(ga.degree(0), dmin);
    }

    #[test]
    fn bfs_improves_adjacent_id_distance_on_random_graphs() {
        // BFS should place neighbors closer in ID space than a random order
        let g = reorder(
            &rmat::generate(&GenConfig { scale: 10, avg_degree: 6, seed: 4 }),
            Ordering::Random,
            13,
        );
        let gap = |g: &CsrGraph| -> f64 {
            let mut total = 0u64;
            let mut cnt = 0u64;
            for (v, u) in g.iter_edges() {
                total += (v as i64 - u as i64).unsigned_abs();
                cnt += 1;
            }
            total as f64 / cnt as f64
        };
        let bfs = reorder(&g, Ordering::Bfs, 0);
        assert!(gap(&bfs) < gap(&g) * 0.8, "bfs {} random {}", gap(&bfs), gap(&g));
    }

    #[test]
    fn skipper_correct_under_all_orderings() {
        // the §V-B claim exercised: correctness under every ordering.
        // NOTE: matching *size* legitimately varies with processing order
        // (degree-ascending greedy finds notably larger matchings); the
        // paper's ordering-independence claim concerns performance, so we
        // only assert the 2-approximation bound here.
        let g = rmat::generate(&GenConfig { scale: 10, avg_degree: 8, seed: 6 });
        let base = Skipper::new(4).run(&g).len() as f64;
        for ord in Ordering::ALL {
            let g2 = reorder(&g, ord, 17);
            let m = Skipper::new(4).run(&g2);
            verify::check(&g2, &m).unwrap_or_else(|e| panic!("{}: {e}", ord.name()));
            let ratio = m.len() as f64 / base;
            assert!((0.5..2.0).contains(&ratio), "{}: ratio {ratio}", ord.name());
        }
    }
}
