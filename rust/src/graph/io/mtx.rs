//! Matrix Market coordinate format (the common interchange format for the
//! paper's public datasets). Supports `pattern`/`real`/`integer` fields and
//! `general`/`symmetric` symmetry; 1-indexed per the spec.

use crate::graph::EdgeList;
use crate::VertexId;
use std::io::{BufRead, BufReader, Read, Write};

/// Parse a Matrix Market coordinate stream.
pub fn read<R: Read>(r: R) -> Result<EdgeList, String> {
    let reader = BufReader::new(r);
    let mut lines = reader.lines();
    let header = lines
        .next()
        .ok_or("empty file")?
        .map_err(|e| e.to_string())?;
    let head = header.to_ascii_lowercase();
    if !head.starts_with("%%matrixmarket matrix coordinate") {
        return Err(format!("unsupported MatrixMarket header: {header}"));
    }
    let symmetric = head.contains("symmetric");
    // skip comments, find size line
    let mut size_line = None;
    for line in lines.by_ref() {
        let line = line.map_err(|e| e.to_string())?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        size_line = Some(t.to_string());
        break;
    }
    let size_line = size_line.ok_or("missing size line")?;
    let mut it = size_line.split_whitespace();
    let rows: usize = it.next().ok_or("bad size line")?.parse().map_err(|e| format!("{e}"))?;
    let cols: usize = it.next().ok_or("bad size line")?.parse().map_err(|e| format!("{e}"))?;
    let nnz: usize = it.next().ok_or("bad size line")?.parse().map_err(|e| format!("{e}"))?;
    let n = rows.max(cols);
    let mut el = EdgeList::new(n);
    el.edges.reserve(nnz);
    for line in lines {
        let line = line.map_err(|e| e.to_string())?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let i: usize = it.next().ok_or("bad entry")?.parse().map_err(|e| format!("{e}"))?;
        let j: usize = it.next().ok_or("bad entry")?.parse().map_err(|e| format!("{e}"))?;
        if i == 0 || j == 0 || i > n || j > n {
            return Err(format!("index out of range: {i} {j} (n={n})"));
        }
        el.push((i - 1) as VertexId, (j - 1) as VertexId);
    }
    if el.edges.len() != nnz {
        return Err(format!("expected {nnz} entries, found {}", el.edges.len()));
    }
    let _ = symmetric; // symmetrization is the builder's job either way
    Ok(el)
}

/// Write an edge list as a `pattern general` Matrix Market file.
pub fn write<W: Write>(w: &mut W, el: &EdgeList) -> std::io::Result<()> {
    writeln!(w, "%%MatrixMarket matrix coordinate pattern general")?;
    writeln!(w, "{} {} {}", el.num_vertices, el.num_vertices, el.edges.len())?;
    for &(u, v) in &el.edges {
        writeln!(w, "{} {}", u + 1, v + 1)?;
    }
    Ok(())
}

/// Read the Matrix Market file at `path`.
pub fn read_file(path: &str) -> Result<EdgeList, String> {
    let f = std::fs::File::open(path).map_err(|e| format!("open {path}: {e}"))?;
    read(f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let el = EdgeList {
            num_vertices: 5,
            edges: vec![(0, 1), (4, 2)],
        };
        let mut buf = Vec::new();
        write(&mut buf, &el).unwrap();
        let back = read(&buf[..]).unwrap();
        assert_eq!(back, el);
    }

    #[test]
    fn parses_with_comments_and_values() {
        let text = "%%MatrixMarket matrix coordinate real general\n\
                    % comment\n\
                    3 3 2\n\
                    1 2 0.5\n\
                    3 1 1.0\n";
        let el = read(text.as_bytes()).unwrap();
        assert_eq!(el.num_vertices, 3);
        assert_eq!(el.edges, vec![(0, 1), (2, 0)]);
    }

    #[test]
    fn rejects_bad_header_and_counts() {
        assert!(read("%%MatrixMarket matrix array real\n1 1 1\n".as_bytes()).is_err());
        let short = "%%MatrixMarket matrix coordinate pattern general\n2 2 2\n1 2\n";
        assert!(read(short.as_bytes()).is_err());
        let oob = "%%MatrixMarket matrix coordinate pattern general\n2 2 1\n1 3\n";
        assert!(read(oob.as_bytes()).is_err());
    }

    #[test]
    fn one_indexing() {
        let text = "%%MatrixMarket matrix coordinate pattern symmetric\n2 2 1\n2 1\n";
        let el = read(text.as_bytes()).unwrap();
        assert_eq!(el.edges, vec![(1, 0)]);
    }
}
