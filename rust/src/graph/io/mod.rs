//! Graph I/O: whitespace edge-list text, Matrix Market coordinate files,
//! and a compact binary CSR format for caching generated suites.

pub mod binary;
pub mod edgelist_txt;
pub mod mtx;
