//! Plain-text edge lists: one `u v` pair per line, `#` comments. Vertex
//! count is `max id + 1` unless a `# vertices: N` header is present.

use crate::graph::EdgeList;
use crate::VertexId;
use std::io::{BufRead, BufReader, Read, Write};

/// Parse a text edge list (one `u v` per line, `#` comments).
pub fn read<R: Read>(r: R) -> Result<EdgeList, String> {
    let reader = BufReader::new(r);
    let mut edges: Vec<(VertexId, VertexId)> = Vec::new();
    let mut declared_n: Option<usize> = None;
    let mut max_id: u64 = 0;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line.map_err(|e| format!("read error: {e}"))?;
        let line = line.trim();
        if let Some(rest) = line.strip_prefix('#') {
            if let Some(v) = rest.trim().strip_prefix("vertices:") {
                declared_n = Some(
                    v.trim()
                        .parse()
                        .map_err(|_| format!("line {}: bad vertices header", lineno + 1))?,
                );
            }
            continue;
        }
        if line.is_empty() {
            continue;
        }
        let mut it = line.split_whitespace();
        let u: u64 = it
            .next()
            .ok_or_else(|| format!("line {}: missing src", lineno + 1))?
            .parse()
            .map_err(|_| format!("line {}: bad src", lineno + 1))?;
        let v: u64 = it
            .next()
            .ok_or_else(|| format!("line {}: missing dst", lineno + 1))?
            .parse()
            .map_err(|_| format!("line {}: bad dst", lineno + 1))?;
        max_id = max_id.max(u).max(v);
        edges.push((u as VertexId, v as VertexId));
    }
    let n = declared_n.unwrap_or(if edges.is_empty() { 0 } else { max_id as usize + 1 });
    if !edges.is_empty() && n <= max_id as usize {
        return Err(format!("declared vertices {n} <= max id {max_id}"));
    }
    Ok(EdgeList {
        num_vertices: n,
        edges,
    })
}

/// Write an edge list as text, with a `# vertices: N` header.
pub fn write<W: Write>(w: &mut W, el: &EdgeList) -> std::io::Result<()> {
    writeln!(w, "# vertices: {}", el.num_vertices)?;
    for &(u, v) in &el.edges {
        writeln!(w, "{u} {v}")?;
    }
    Ok(())
}

/// Read the text edge list at `path`.
pub fn read_file(path: &str) -> Result<EdgeList, String> {
    let f = std::fs::File::open(path).map_err(|e| format!("open {path}: {e}"))?;
    read(f)
}

/// Write `el` to `path` as text.
pub fn write_file(path: &str, el: &EdgeList) -> Result<(), String> {
    let mut f = std::fs::File::create(path).map_err(|e| format!("create {path}: {e}"))?;
    write(&mut f, el).map_err(|e| format!("write {path}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let el = EdgeList {
            num_vertices: 10,
            edges: vec![(0, 1), (5, 9), (3, 3)],
        };
        let mut buf = Vec::new();
        write(&mut buf, &el).unwrap();
        let back = read(&buf[..]).unwrap();
        assert_eq!(back, el);
    }

    #[test]
    fn infers_vertex_count() {
        let el = read("0 1\n2 7\n".as_bytes()).unwrap();
        assert_eq!(el.num_vertices, 8);
        assert_eq!(el.edges.len(), 2);
    }

    #[test]
    fn skips_comments_and_blanks() {
        let el = read("# hello\n\n0 1\n# another\n1 2\n".as_bytes()).unwrap();
        assert_eq!(el.edges, vec![(0, 1), (1, 2)]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(read("0 x\n".as_bytes()).is_err());
        assert!(read("justone\n".as_bytes()).is_err());
        assert!(read("# vertices: 2\n0 5\n".as_bytes()).is_err());
    }

    #[test]
    fn empty_input() {
        let el = read("".as_bytes()).unwrap();
        assert_eq!(el.num_vertices, 0);
        assert!(el.edges.is_empty());
    }
}
