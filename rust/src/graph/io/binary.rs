//! Compact binary CSR cache format (`.skg`): little-endian
//! `magic("SKPGRPH1") | n:u64 | slots:u64 | offsets[(n+1)×u64] | neighbors[slots×u32]`.
//! Used by the coordinator to cache generated suite graphs between runs.

use crate::graph::CsrGraph;
use crate::{EdgeIdx, VertexId};
use std::io::{BufReader, BufWriter, Read, Write};

/// Shared with [`crate::graph::stream::SkgEdgeSource`], which re-reads this
/// format with two streaming cursors — keep writer and readers in one place.
pub(crate) const MAGIC: &[u8; 8] = b"SKPGRPH1";

/// Bytes before the offsets array: magic + n + slots.
pub(crate) const HEADER_BYTES: u64 = 8 + 8 + 8;

/// Write a CSR in `.skg` format.
pub fn write<W: Write>(w: &mut W, g: &CsrGraph) -> std::io::Result<()> {
    let mut w = BufWriter::new(w);
    w.write_all(MAGIC)?;
    w.write_all(&(g.num_vertices() as u64).to_le_bytes())?;
    w.write_all(&(g.num_edge_slots() as u64).to_le_bytes())?;
    for &o in g.offsets() {
        w.write_all(&o.to_le_bytes())?;
    }
    for &nb in g.neighbors_raw() {
        w.write_all(&nb.to_le_bytes())?;
    }
    w.flush()
}

/// Read a `.skg` stream back into a CSR.
pub fn read<R: Read>(r: R) -> Result<CsrGraph, String> {
    let mut r = BufReader::new(r);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).map_err(|e| format!("magic: {e}"))?;
    if &magic != MAGIC {
        return Err("bad magic (not a .skg file)".into());
    }
    let n = read_u64(&mut r)? as usize;
    let slots = read_u64(&mut r)? as usize;
    let mut offsets: Vec<EdgeIdx> = Vec::with_capacity(n + 1);
    for _ in 0..=n {
        offsets.push(read_u64(&mut r)?);
    }
    let mut neighbors: Vec<VertexId> = Vec::with_capacity(slots);
    let mut buf4 = [0u8; 4];
    for _ in 0..slots {
        r.read_exact(&mut buf4).map_err(|e| format!("neighbors: {e}"))?;
        neighbors.push(u32::from_le_bytes(buf4));
    }
    CsrGraph::from_parts(offsets, neighbors)
}

pub(crate) fn read_u64<R: Read>(r: &mut R) -> Result<u64, String> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b).map_err(|e| format!("u64: {e}"))?;
    Ok(u64::from_le_bytes(b))
}

pub(crate) fn read_u32<R: Read>(r: &mut R) -> Result<u32, String> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).map_err(|e| format!("u32: {e}"))?;
    Ok(u32::from_le_bytes(b))
}

/// Write `g` to `path` in `.skg` format.
pub fn write_file(path: &str, g: &CsrGraph) -> Result<(), String> {
    let mut f = std::fs::File::create(path).map_err(|e| format!("create {path}: {e}"))?;
    write(&mut f, g).map_err(|e| format!("write {path}: {e}"))
}

/// Read the `.skg` file at `path`.
pub fn read_file(path: &str) -> Result<CsrGraph, String> {
    let f = std::fs::File::open(path).map_err(|e| format!("open {path}: {e}"))?;
    read(f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{rmat, GenConfig};

    #[test]
    fn roundtrip() {
        let g = rmat::generate(&GenConfig { scale: 8, avg_degree: 6, seed: 2 });
        let mut buf = Vec::new();
        write(&mut buf, &g).unwrap();
        let back = read(&buf[..]).unwrap();
        assert_eq!(back, g);
    }

    #[test]
    fn rejects_bad_magic() {
        let buf = b"NOTMAGIC\x00\x00\x00\x00\x00\x00\x00\x00".to_vec();
        assert!(read(&buf[..]).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let g = rmat::generate(&GenConfig { scale: 6, avg_degree: 4, seed: 2 });
        let mut buf = Vec::new();
        write(&mut buf, &g).unwrap();
        buf.truncate(buf.len() - 3);
        assert!(read(&buf[..]).is_err());
    }

    #[test]
    fn empty_graph_roundtrip() {
        let g = CsrGraph::from_parts(vec![0], vec![]).unwrap();
        let mut buf = Vec::new();
        write(&mut buf, &g).unwrap();
        assert_eq!(read(&buf[..]).unwrap(), g);
    }
}
