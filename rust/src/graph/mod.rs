//! Graph substrate: CSR storage, COO edge lists, builders, loaders, and the
//! synthetic generators that stand in for the paper's dataset suite.
//!
//! Conventions (paper §II-A):
//! * Graphs are undirected; a *symmetric* CSR stores each edge in both
//!   endpoints' neighbor lists. Skipper also accepts non-symmetrized CSR
//!   (each edge present for at least one endpoint) — see §V-C "Input Format
//!   & Symmetrization" — and the EMS baselines require symmetric input.
//! * `offsets` has |V|+1 entries; `neighbors[offsets[v]..offsets[v+1]]` are
//!   v's neighbors.

pub mod builder;
pub mod gen;
pub mod io;
pub mod ordering;
pub mod stream;

use crate::{EdgeIdx, VertexId};

/// Compressed Sparse Row graph (paper §II-A).
#[derive(Clone, Debug, PartialEq)]
pub struct CsrGraph {
    offsets: Vec<EdgeIdx>,
    neighbors: Vec<VertexId>,
}

impl CsrGraph {
    /// Construct from raw parts, validating CSR invariants.
    pub fn from_parts(offsets: Vec<EdgeIdx>, neighbors: Vec<VertexId>) -> Result<Self, String> {
        if offsets.is_empty() {
            return Err("offsets must have at least one entry".into());
        }
        if offsets[0] != 0 {
            return Err("offsets[0] must be 0".into());
        }
        if *offsets.last().unwrap() as usize != neighbors.len() {
            return Err(format!(
                "offsets[last]={} != neighbors.len()={}",
                offsets.last().unwrap(),
                neighbors.len()
            ));
        }
        if offsets.windows(2).any(|w| w[0] > w[1]) {
            return Err("offsets must be non-decreasing".into());
        }
        let n = (offsets.len() - 1) as u64;
        if neighbors.iter().any(|&u| u as u64 >= n) {
            return Err("neighbor id out of range".into());
        }
        Ok(Self { offsets, neighbors })
    }

    /// Number of vertices |V|.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of stored edge *slots* (2|E| for a symmetric graph).
    #[inline]
    pub fn num_edge_slots(&self) -> usize {
        self.neighbors.len()
    }

    /// Number of undirected edges assuming symmetric storage.
    #[inline]
    pub fn num_undirected_edges(&self) -> usize {
        self.neighbors.len() / 2
    }

    #[inline]
    /// Stored degree of `v`.
    pub fn degree(&self, v: VertexId) -> usize {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as usize
    }

    #[inline]
    /// `v`’s neighbor slice.
    pub fn neighbors(&self, v: VertexId) -> &[VertexId] {
        &self.neighbors[self.offsets[v as usize] as usize..self.offsets[v as usize + 1] as usize]
    }

    #[inline]
    /// The raw CSR offsets array (`|V|+1` entries).
    pub fn offsets(&self) -> &[EdgeIdx] {
        &self.offsets
    }

    #[inline]
    /// The raw concatenated neighbors array.
    pub fn neighbors_raw(&self) -> &[VertexId] {
        &self.neighbors
    }

    /// Iterate all stored edge slots as `(src, dst)` pairs in CSR order.
    pub fn iter_edges(&self) -> impl Iterator<Item = (VertexId, VertexId)> + '_ {
        (0..self.num_vertices() as VertexId)
            .flat_map(move |v| self.neighbors(v).iter().map(move |&u| (v, u)))
    }

    /// Check whether each stored edge `(v,u)` also appears as `(u,v)`.
    pub fn is_symmetric(&self) -> bool {
        // neighbor lists from our builder are sorted; fall back to linear scan
        // if not (correctness over speed here — used in tests/validation).
        self.iter_edges().all(|(v, u)| {
            let ns = self.neighbors(u);
            if ns.windows(2).all(|w| w[0] <= w[1]) {
                ns.binary_search(&v).is_ok()
            } else {
                ns.contains(&v)
            }
        })
    }

    /// Maximum degree.
    pub fn max_degree(&self) -> usize {
        (0..self.num_vertices() as VertexId)
            .map(|v| self.degree(v))
            .max()
            .unwrap_or(0)
    }

    /// Approximate resident bytes (topology only).
    pub fn memory_bytes(&self) -> usize {
        self.offsets.len() * std::mem::size_of::<EdgeIdx>()
            + self.neighbors.len() * std::mem::size_of::<VertexId>()
    }

    /// Degree distribution summary `(min, median, max, mean)`.
    pub fn degree_summary(&self) -> (usize, usize, usize, f64) {
        let n = self.num_vertices();
        if n == 0 {
            return (0, 0, 0, 0.0);
        }
        let mut degs: Vec<usize> = (0..n as VertexId).map(|v| self.degree(v)).collect();
        degs.sort_unstable();
        let mean = degs.iter().sum::<usize>() as f64 / n as f64;
        (degs[0], degs[n / 2], degs[n - 1], mean)
    }
}

/// Coordinate-format (COO) edge list. Self-loops and duplicates are allowed
/// at this stage; [`builder`] normalizes on conversion to CSR.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct EdgeList {
    /// Vertex universe `0..num_vertices`.
    pub num_vertices: usize,
    /// Edge pairs in arrival order (may contain duplicates/self-loops).
    pub edges: Vec<(VertexId, VertexId)>,
}

impl EdgeList {
    /// Empty list over `0..num_vertices`.
    pub fn new(num_vertices: usize) -> Self {
        Self {
            num_vertices,
            edges: Vec::new(),
        }
    }

    /// Append one edge (both endpoints must be in range).
    pub fn push(&mut self, u: VertexId, v: VertexId) {
        debug_assert!((u as usize) < self.num_vertices && (v as usize) < self.num_vertices);
        self.edges.push((u, v));
    }

    /// Number of stored pairs.
    pub fn len(&self) -> usize {
        self.edges.len()
    }

    /// True when no pairs are stored.
    pub fn is_empty(&self) -> bool {
        self.edges.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> CsrGraph {
        // 0-1, 0-2, 1-2, 2-3 symmetric
        CsrGraph::from_parts(
            vec![0, 2, 4, 7, 8],
            vec![1, 2, 0, 2, 0, 1, 3, 2],
        )
        .unwrap()
    }

    #[test]
    fn basic_accessors() {
        let g = tiny();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edge_slots(), 8);
        assert_eq!(g.num_undirected_edges(), 4);
        assert_eq!(g.degree(2), 3);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.max_degree(), 3);
        assert!(g.is_symmetric());
    }

    #[test]
    fn iter_edges_covers_all_slots() {
        let g = tiny();
        let edges: Vec<_> = g.iter_edges().collect();
        assert_eq!(edges.len(), 8);
        assert_eq!(edges[0], (0, 1));
        assert_eq!(edges[7], (3, 2));
    }

    #[test]
    fn asymmetric_detected() {
        let g = CsrGraph::from_parts(vec![0, 1, 1], vec![1]).unwrap();
        assert!(!g.is_symmetric());
    }

    #[test]
    fn from_parts_validates() {
        assert!(CsrGraph::from_parts(vec![], vec![]).is_err());
        assert!(CsrGraph::from_parts(vec![1, 2], vec![0]).is_err()); // offsets[0] != 0
        assert!(CsrGraph::from_parts(vec![0, 2], vec![0]).is_err()); // last != len
        assert!(CsrGraph::from_parts(vec![0, 2, 1], vec![0, 0]).is_err()); // decreasing
        assert!(CsrGraph::from_parts(vec![0, 1], vec![5]).is_err()); // id range
    }

    #[test]
    fn degree_summary_sane() {
        let g = tiny();
        let (min, _med, max, mean) = g.degree_summary();
        assert_eq!(min, 1);
        assert_eq!(max, 3);
        assert!((mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_graph() {
        let g = CsrGraph::from_parts(vec![0], vec![]).unwrap();
        assert_eq!(g.num_vertices(), 0);
        assert_eq!(g.max_degree(), 0);
        assert!(g.is_symmetric());
    }
}
