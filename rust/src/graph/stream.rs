//! Streaming edge delivery — the [`EdgeSource`] abstraction.
//!
//! Skipper decides each edge's fate the moment it is seen (paper §IV), so
//! the matcher never needs a materialized graph: any producer that can hand
//! over `(u, v)` pairs *once*, in chunks, is a valid input. This module
//! defines that contract plus sources for every ingest path the repo knows:
//!
//! * [`BatchEdgeSource`] — an in-memory slice (the incremental/batch-update
//!   scenario, and the substrate for equivalence tests);
//! * [`TextEdgeSource`] — whitespace `u v` edge lists (`.txt`/`.el`),
//!   parsed line-by-line off disk;
//! * [`MtxEdgeSource`] — Matrix Market coordinate files, streamed past the
//!   size line;
//! * [`SkgEdgeSource`] — the compact binary CSR cache format, streamed with
//!   two sequential cursors (offsets + neighbors) so the arrays are never
//!   resident;
//! * [`SyntheticEdgeSource`] — Erdős–Rényi / RMAT generators emitting edges
//!   on the fly;
//! * [`CsrEdgeSource`] — adapter over an already-materialized
//!   [`CsrGraph`] (for A/B comparisons against the CSR driver).
//!
//! Peak topology-resident memory of a streaming run is the chunk buffers
//! plus Skipper's one byte of state per vertex — independent of |E| —
//! versus `(|V|+1)·8 + slots·4` bytes for a CSR.

use super::io::binary;
use super::{CsrGraph, EdgeList};
use crate::util::rng::Xoshiro256pp;
use crate::VertexId;
use std::fs::File;
use std::io::{BufRead, BufReader, Read, Seek, SeekFrom};

/// A one-shot, chunked producer of edges.
///
/// Contract: `vertex_bound()` is an exclusive upper bound on every vertex
/// id the source will ever emit (consumers size per-vertex state from it);
/// `next_chunk` appends up to `max_edges` edges to `chunk` (which it clears
/// first) and returns how many were appended — `0` means the stream is
/// exhausted. Each edge is delivered exactly once; sources backed by
/// symmetric storage (e.g. `.skg`) deliver each *undirected* edge once per
/// stored copy, which Skipper treats as already-covered on the second
/// sighting.
///
/// # Example
///
/// Pull chunks by hand, or hand any source to the streaming matcher:
///
/// ```
/// use skipper::graph::stream::{BatchEdgeSource, EdgeSource};
/// use skipper::matching::streaming::StreamingSkipper;
///
/// let edges = [(0, 1), (2, 3)];
/// let mut source = BatchEdgeSource::new(4, &edges);
/// assert_eq!(source.vertex_bound(), 4);
/// let mut chunk = Vec::new();
/// assert_eq!(source.next_chunk(&mut chunk, 64).unwrap(), 2);
/// assert_eq!(source.next_chunk(&mut chunk, 64).unwrap(), 0, "one-shot");
///
/// // ingest→match without ever materializing a graph
/// let report = StreamingSkipper::new(2)
///     .run(BatchEdgeSource::new(4, &edges))
///     .unwrap();
/// assert_eq!(report.matching.len(), 2);
/// ```
pub trait EdgeSource {
    /// Exclusive upper bound on vertex ids this source emits.
    fn vertex_bound(&self) -> usize;

    /// Pull the next chunk. Clears `chunk`, appends up to `max_edges`
    /// pairs, returns the number appended (0 = exhausted).
    fn next_chunk(
        &mut self,
        chunk: &mut Vec<(VertexId, VertexId)>,
        max_edges: usize,
    ) -> Result<usize, String>;

    /// Total edges this source expects to emit, when known up front.
    fn edge_hint(&self) -> Option<u64> {
        None
    }
}

/// Drain a source into an [`EdgeList`] (testing / verification only — this
/// materializes exactly what streaming avoids).
pub fn collect_edges<S: EdgeSource>(mut source: S) -> Result<EdgeList, String> {
    let mut el = EdgeList::new(source.vertex_bound());
    let mut chunk = Vec::new();
    while source.next_chunk(&mut chunk, 65_536)? > 0 {
        el.edges.extend_from_slice(&chunk);
    }
    Ok(el)
}

// ---------------------------------------------------------------------------
// In-memory batch
// ---------------------------------------------------------------------------

/// A borrowed in-memory batch of edges — the "edges arrive as updates"
/// scenario that [`crate::matching::incremental`] rides on.
pub struct BatchEdgeSource<'a> {
    edges: &'a [(VertexId, VertexId)],
    num_vertices: usize,
    pos: usize,
    /// When set, edges already seen in this batch (either orientation) are
    /// skipped instead of delivered again.
    seen: Option<std::collections::HashSet<(VertexId, VertexId)>>,
}

impl<'a> BatchEdgeSource<'a> {
    /// Source over a borrowed edge slice with vertex bound `num_vertices`.
    pub fn new(num_vertices: usize, edges: &'a [(VertexId, VertexId)]) -> Self {
        Self { edges, num_vertices, pos: 0, seen: None }
    }

    /// Skip duplicate edges within the batch, counting `(u,v)` and `(v,u)`
    /// as the same edge. The update paths (incremental inserts, the dynamic
    /// engine) enable this so a client repeating an insert doesn't inflate
    /// the per-batch "edges processed" telemetry; the exact-replay paths
    /// (stream-equivalence tests) leave it off because the *multiset* of
    /// delivered edges is what they compare.
    pub fn with_dedup(mut self) -> Self {
        self.seen = Some(std::collections::HashSet::new());
        self
    }
}

impl EdgeSource for BatchEdgeSource<'_> {
    fn vertex_bound(&self) -> usize {
        self.num_vertices
    }

    fn next_chunk(
        &mut self,
        chunk: &mut Vec<(VertexId, VertexId)>,
        max_edges: usize,
    ) -> Result<usize, String> {
        chunk.clear();
        while chunk.len() < max_edges && self.pos < self.edges.len() {
            let (u, v) = self.edges[self.pos];
            self.pos += 1;
            if (u as usize) >= self.num_vertices || (v as usize) >= self.num_vertices {
                return Err(format!(
                    "edge ({u},{v}) out of range (vertex bound {})",
                    self.num_vertices
                ));
            }
            if let Some(seen) = &mut self.seen {
                if !seen.insert((u.min(v), u.max(v))) {
                    continue;
                }
            }
            chunk.push((u, v));
        }
        Ok(chunk.len())
    }

    fn edge_hint(&self) -> Option<u64> {
        Some(self.edges.len() as u64)
    }
}

// ---------------------------------------------------------------------------
// Plain-text edge lists
// ---------------------------------------------------------------------------

/// Streaming reader for whitespace `u v` edge lists (`#` comments, optional
/// `# vertices: N` header). Without the header the file is pre-scanned once
/// to learn the vertex bound — an extra I/O pass, but still O(1) memory.
pub struct TextEdgeSource {
    reader: BufReader<File>,
    num_vertices: usize,
    lineno: usize,
    line: String,
}

impl TextEdgeSource {
    /// Open a text edge-list file, learning the vertex bound from the
    /// header or a pre-scan.
    pub fn open(path: &str) -> Result<Self, String> {
        let num_vertices = match Self::header_bound(path)? {
            Some(n) => n,
            None => Self::scan_bound(path)?,
        };
        let f = File::open(path).map_err(|e| format!("open {path}: {e}"))?;
        Ok(Self {
            reader: BufReader::new(f),
            num_vertices,
            lineno: 0,
            line: String::new(),
        })
    }

    /// Look for a `# vertices: N` header among the leading comment lines.
    fn header_bound(path: &str) -> Result<Option<usize>, String> {
        let f = File::open(path).map_err(|e| format!("open {path}: {e}"))?;
        let mut r = BufReader::new(f);
        let mut line = String::new();
        loop {
            line.clear();
            let read = r.read_line(&mut line).map_err(|e| format!("read {path}: {e}"))?;
            if read == 0 {
                return Ok(None);
            }
            let t = line.trim();
            if t.is_empty() {
                continue;
            }
            match t.strip_prefix('#') {
                Some(rest) => {
                    if let Some(v) = rest.trim().strip_prefix("vertices:") {
                        let n = v
                            .trim()
                            .parse()
                            .map_err(|_| format!("{path}: bad vertices header"))?;
                        return Ok(Some(n));
                    }
                }
                None => return Ok(None), // first edge line before any header
            }
        }
    }

    /// One cheap streaming pass to find `max id + 1`.
    fn scan_bound(path: &str) -> Result<usize, String> {
        let f = File::open(path).map_err(|e| format!("open {path}: {e}"))?;
        let mut r = BufReader::new(f);
        let mut line = String::new();
        let mut max_id: u64 = 0;
        let mut any = false;
        let mut lineno = 0usize;
        loop {
            line.clear();
            let read = r.read_line(&mut line).map_err(|e| format!("read {path}: {e}"))?;
            if read == 0 {
                break;
            }
            lineno += 1;
            if let Some((u, v)) = parse_edge_line(&line, lineno)? {
                max_id = max_id.max(u as u64).max(v as u64);
                any = true;
            }
        }
        Ok(if any { max_id as usize + 1 } else { 0 })
    }
}

/// Parse one text line into an edge; `Ok(None)` for comments/blank lines.
fn parse_edge_line(line: &str, lineno: usize) -> Result<Option<(VertexId, VertexId)>, String> {
    let t = line.trim();
    if t.is_empty() || t.starts_with('#') {
        return Ok(None);
    }
    let mut it = t.split_whitespace();
    let u: u64 = it
        .next()
        .ok_or_else(|| format!("line {lineno}: missing src"))?
        .parse()
        .map_err(|_| format!("line {lineno}: bad src"))?;
    let v: u64 = it
        .next()
        .ok_or_else(|| format!("line {lineno}: missing dst"))?
        .parse()
        .map_err(|_| format!("line {lineno}: bad dst"))?;
    Ok(Some((u as VertexId, v as VertexId)))
}

impl EdgeSource for TextEdgeSource {
    fn vertex_bound(&self) -> usize {
        self.num_vertices
    }

    fn next_chunk(
        &mut self,
        chunk: &mut Vec<(VertexId, VertexId)>,
        max_edges: usize,
    ) -> Result<usize, String> {
        chunk.clear();
        while chunk.len() < max_edges {
            self.line.clear();
            let read = self
                .reader
                .read_line(&mut self.line)
                .map_err(|e| format!("read error: {e}"))?;
            if read == 0 {
                break;
            }
            self.lineno += 1;
            if let Some((u, v)) = parse_edge_line(&self.line, self.lineno)? {
                if (u as usize) >= self.num_vertices || (v as usize) >= self.num_vertices {
                    return Err(format!(
                        "line {}: edge ({u},{v}) exceeds vertex bound {}",
                        self.lineno, self.num_vertices
                    ));
                }
                chunk.push((u, v));
            }
        }
        Ok(chunk.len())
    }
}

// ---------------------------------------------------------------------------
// Matrix Market
// ---------------------------------------------------------------------------

/// Streaming reader for Matrix Market coordinate files. The size line gives
/// the vertex bound and entry count up front; entries stream after it.
pub struct MtxEdgeSource {
    reader: BufReader<File>,
    num_vertices: usize,
    nnz: u64,
    seen: u64,
    line: String,
}

impl MtxEdgeSource {
    /// Open a Matrix Market file and parse its size line.
    pub fn open(path: &str) -> Result<Self, String> {
        let f = File::open(path).map_err(|e| format!("open {path}: {e}"))?;
        let mut reader = BufReader::new(f);
        let mut line = String::new();
        reader
            .read_line(&mut line)
            .map_err(|e| format!("read {path}: {e}"))?;
        let head = line.to_ascii_lowercase();
        if !head.starts_with("%%matrixmarket matrix coordinate") {
            return Err(format!("unsupported MatrixMarket header: {}", line.trim()));
        }
        // skip comments, find the size line
        loop {
            line.clear();
            let read = reader
                .read_line(&mut line)
                .map_err(|e| format!("read {path}: {e}"))?;
            if read == 0 {
                return Err("missing size line".into());
            }
            let t = line.trim();
            if t.is_empty() || t.starts_with('%') {
                continue;
            }
            break;
        }
        let mut it = line.split_whitespace();
        let rows: usize = it
            .next()
            .ok_or("bad size line")?
            .parse()
            .map_err(|e| format!("{e}"))?;
        let cols: usize = it
            .next()
            .ok_or("bad size line")?
            .parse()
            .map_err(|e| format!("{e}"))?;
        let nnz: u64 = it
            .next()
            .ok_or("bad size line")?
            .parse()
            .map_err(|e| format!("{e}"))?;
        Ok(Self {
            reader,
            num_vertices: rows.max(cols),
            nnz,
            seen: 0,
            line: String::new(),
        })
    }
}

impl EdgeSource for MtxEdgeSource {
    fn vertex_bound(&self) -> usize {
        self.num_vertices
    }

    fn next_chunk(
        &mut self,
        chunk: &mut Vec<(VertexId, VertexId)>,
        max_edges: usize,
    ) -> Result<usize, String> {
        chunk.clear();
        while chunk.len() < max_edges {
            self.line.clear();
            let read = self
                .reader
                .read_line(&mut self.line)
                .map_err(|e| format!("read error: {e}"))?;
            if read == 0 {
                if self.seen != self.nnz {
                    return Err(format!("expected {} entries, found {}", self.nnz, self.seen));
                }
                break;
            }
            let t = self.line.trim();
            if t.is_empty() || t.starts_with('%') {
                continue;
            }
            let mut it = t.split_whitespace();
            let i: usize = it
                .next()
                .ok_or("bad entry")?
                .parse()
                .map_err(|e| format!("{e}"))?;
            let j: usize = it
                .next()
                .ok_or("bad entry")?
                .parse()
                .map_err(|e| format!("{e}"))?;
            let n = self.num_vertices;
            if i == 0 || j == 0 || i > n || j > n {
                return Err(format!("index out of range: {i} {j} (n={n})"));
            }
            chunk.push(((i - 1) as VertexId, (j - 1) as VertexId));
            self.seen += 1;
        }
        Ok(chunk.len())
    }

    fn edge_hint(&self) -> Option<u64> {
        Some(self.nnz)
    }
}

// ---------------------------------------------------------------------------
// Binary .skg (CSR cache format)
// ---------------------------------------------------------------------------

/// Streaming reader for the `.skg` binary CSR format. Two file cursors
/// advance in lockstep — one through the offsets array, one through the
/// neighbors array — so neither array is ever memory-resident. Emits one
/// `(v, neighbor)` pair per stored slot.
pub struct SkgEdgeSource {
    offsets: BufReader<File>,
    neighbors: BufReader<File>,
    n: u64,
    slots: u64,
    /// Vertex whose neighbor run is currently streaming.
    cur: u64,
    /// Next vertex whose offset has not been consumed yet.
    next_v: u64,
    prev_off: u64,
    /// Neighbors remaining in `cur`'s run.
    rem: u64,
    emitted: u64,
}

impl SkgEdgeSource {
    /// Open a `.skg` CSR cache with two sequential cursors (offsets +
    /// neighbors) so neither array is ever resident.
    pub fn open(path: &str) -> Result<Self, String> {
        let f = File::open(path).map_err(|e| format!("open {path}: {e}"))?;
        let mut offsets = BufReader::new(f);
        let mut magic = [0u8; 8];
        offsets
            .read_exact(&mut magic)
            .map_err(|e| format!("magic: {e}"))?;
        if &magic != binary::MAGIC {
            return Err("bad magic (not a .skg file)".into());
        }
        let n = binary::read_u64(&mut offsets)?;
        let slots = binary::read_u64(&mut offsets)?;
        // offsets[0] must be 0
        let first = binary::read_u64(&mut offsets)?;
        if first != 0 {
            return Err("offsets[0] must be 0".into());
        }
        let mut nf = File::open(path).map_err(|e| format!("open {path}: {e}"))?;
        nf.seek(SeekFrom::Start(binary::HEADER_BYTES + (n + 1) * 8))
            .map_err(|e| format!("seek {path}: {e}"))?;
        Ok(Self {
            offsets,
            neighbors: BufReader::new(nf),
            n,
            slots,
            cur: 0,
            next_v: 0,
            prev_off: 0,
            rem: 0,
            emitted: 0,
        })
    }
}

impl EdgeSource for SkgEdgeSource {
    fn vertex_bound(&self) -> usize {
        self.n as usize
    }

    fn next_chunk(
        &mut self,
        chunk: &mut Vec<(VertexId, VertexId)>,
        max_edges: usize,
    ) -> Result<usize, String> {
        chunk.clear();
        while chunk.len() < max_edges {
            while self.rem == 0 {
                if self.next_v >= self.n {
                    if self.emitted != self.slots {
                        return Err(format!(
                            "offsets cover {} slots, header says {}",
                            self.emitted, self.slots
                        ));
                    }
                    return Ok(chunk.len());
                }
                let next_off = binary::read_u64(&mut self.offsets)?;
                if next_off < self.prev_off || next_off > self.slots {
                    return Err("offsets must be non-decreasing and <= slots".into());
                }
                self.rem = next_off - self.prev_off;
                self.prev_off = next_off;
                self.cur = self.next_v;
                self.next_v += 1;
            }
            let nb = binary::read_u32(&mut self.neighbors)?;
            if (nb as u64) >= self.n {
                return Err(format!("neighbor id {nb} out of range (n={})", self.n));
            }
            chunk.push((self.cur as VertexId, nb));
            self.rem -= 1;
            self.emitted += 1;
        }
        Ok(chunk.len())
    }

    fn edge_hint(&self) -> Option<u64> {
        Some(self.slots)
    }
}

// ---------------------------------------------------------------------------
// Synthetic generators
// ---------------------------------------------------------------------------

#[derive(Clone, Copy)]
enum Synthetic {
    Er { n: usize },
    Rmat { scale: u32, probs: (f64, f64, f64, f64) },
}

/// Generator-backed source: edges are sampled on demand, so the "graph"
/// never exists in memory at all. Deterministic given the seed and chunking
/// (the RNG stream is consumed edge-by-edge regardless of chunk size).
pub struct SyntheticEdgeSource {
    kind: Synthetic,
    rng: Xoshiro256pp,
    remaining: u64,
    total: u64,
}

impl SyntheticEdgeSource {
    /// Erdős–Rényi G(n, m): `m` uniform random edges, the same stream as
    /// [`crate::graph::gen::erdos_renyi::edges`].
    pub fn erdos_renyi(n: usize, m: usize, seed: u64) -> Self {
        Self {
            kind: Synthetic::Er { n },
            rng: Xoshiro256pp::new(seed),
            remaining: m as u64,
            total: m as u64,
        }
    }

    /// RMAT with Graph500 probabilities, the same stream as
    /// [`crate::graph::gen::rmat::edges_with_probs`].
    pub fn rmat(cfg: &crate::graph::gen::GenConfig) -> Self {
        Self {
            kind: Synthetic::Rmat {
                scale: cfg.scale,
                probs: crate::graph::gen::rmat::GRAPH500_PROBS,
            },
            rng: Xoshiro256pp::new(cfg.seed),
            remaining: cfg.num_edges() as u64,
            total: cfg.num_edges() as u64,
        }
    }
}

impl EdgeSource for SyntheticEdgeSource {
    fn vertex_bound(&self) -> usize {
        match self.kind {
            Synthetic::Er { n } => n,
            Synthetic::Rmat { scale, .. } => 1usize << scale,
        }
    }

    fn next_chunk(
        &mut self,
        chunk: &mut Vec<(VertexId, VertexId)>,
        max_edges: usize,
    ) -> Result<usize, String> {
        chunk.clear();
        let take = (max_edges as u64).min(self.remaining);
        for _ in 0..take {
            let e = match self.kind {
                Synthetic::Er { n } => (
                    self.rng.next_usize(n) as VertexId,
                    self.rng.next_usize(n) as VertexId,
                ),
                Synthetic::Rmat { scale, probs } => {
                    crate::graph::gen::rmat::sample_edge(&mut self.rng, scale, probs)
                }
            };
            chunk.push(e);
        }
        self.remaining -= take;
        Ok(chunk.len())
    }

    fn edge_hint(&self) -> Option<u64> {
        Some(self.total)
    }
}

// ---------------------------------------------------------------------------
// CSR adapter
// ---------------------------------------------------------------------------

/// Streams every stored slot of a materialized CSR in CSR order. Only
/// useful for A/B comparisons — the CSR is obviously already resident.
pub struct CsrEdgeSource<'a> {
    g: &'a CsrGraph,
    v: usize,
    i: usize,
}

impl<'a> CsrEdgeSource<'a> {
    /// Stream the stored edge slots of an already-materialized CSR.
    pub fn new(g: &'a CsrGraph) -> Self {
        Self { g, v: 0, i: 0 }
    }
}

impl EdgeSource for CsrEdgeSource<'_> {
    fn vertex_bound(&self) -> usize {
        self.g.num_vertices()
    }

    fn next_chunk(
        &mut self,
        chunk: &mut Vec<(VertexId, VertexId)>,
        max_edges: usize,
    ) -> Result<usize, String> {
        chunk.clear();
        let n = self.g.num_vertices();
        while chunk.len() < max_edges && self.v < n {
            let ns = self.g.neighbors(self.v as VertexId);
            if self.i >= ns.len() {
                self.v += 1;
                self.i = 0;
                continue;
            }
            chunk.push((self.v as VertexId, ns[self.i]));
            self.i += 1;
        }
        Ok(chunk.len())
    }

    fn edge_hint(&self) -> Option<u64> {
        Some(self.g.num_edge_slots() as u64)
    }
}

/// Open a file-backed [`EdgeSource`] by extension (`.skg`, `.mtx`,
/// `.txt`/`.el`) — the streaming twin of the CLI's eager `load_graph`.
pub fn open_path(path: &str) -> Result<Box<dyn EdgeSource + Send>, String> {
    if path.ends_with(".skg") {
        return Ok(Box::new(SkgEdgeSource::open(path)?));
    }
    if path.ends_with(".mtx") {
        return Ok(Box::new(MtxEdgeSource::open(path)?));
    }
    if path.ends_with(".txt") || path.ends_with(".el") {
        return Ok(Box::new(TextEdgeSource::open(path)?));
    }
    Err(format!("unknown edge-stream format {path:?} (.skg/.mtx/.txt/.el)"))
}

impl EdgeSource for Box<dyn EdgeSource + Send> {
    fn vertex_bound(&self) -> usize {
        (**self).vertex_bound()
    }

    fn next_chunk(
        &mut self,
        chunk: &mut Vec<(VertexId, VertexId)>,
        max_edges: usize,
    ) -> Result<usize, String> {
        (**self).next_chunk(chunk, max_edges)
    }

    fn edge_hint(&self) -> Option<u64> {
        (**self).edge_hint()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{erdos_renyi, rmat, GenConfig};
    use crate::graph::io::{binary, edgelist_txt, mtx};

    fn tmp(name: &str) -> String {
        let dir = std::env::temp_dir().join("skipper_stream_tests");
        let _ = std::fs::create_dir_all(&dir);
        dir.join(name).to_str().unwrap().to_string()
    }

    fn drain<S: EdgeSource>(mut s: S, chunk_size: usize) -> Vec<(VertexId, VertexId)> {
        let mut out = Vec::new();
        let mut chunk = Vec::new();
        while s.next_chunk(&mut chunk, chunk_size).unwrap() > 0 {
            out.extend_from_slice(&chunk);
        }
        out
    }

    #[test]
    fn batch_source_streams_all_edges_across_chunk_sizes() {
        let edges: Vec<(VertexId, VertexId)> = (0..100u32).map(|i| (i, (i + 1) % 100)).collect();
        for cs in [1, 7, 100, 1000] {
            let s = BatchEdgeSource::new(100, &edges);
            assert_eq!(drain(s, cs), edges, "chunk size {cs}");
        }
    }

    #[test]
    fn batch_source_dedup_skips_repeats_in_both_orientations() {
        let edges = [(0u32, 1u32), (1, 0), (0, 1), (2, 3), (3, 2), (0, 2)];
        // default: the full multiset streams through
        assert_eq!(drain(BatchEdgeSource::new(4, &edges), 2).len(), 6);
        // dedup: one copy per undirected edge, first orientation wins
        let deduped = drain(BatchEdgeSource::new(4, &edges).with_dedup(), 2);
        assert_eq!(deduped, vec![(0, 1), (2, 3), (0, 2)]);
        // an all-duplicate tail must read as exhaustion, not an early stop
        let dup_tail = [(0u32, 1u32), (1, 0), (1, 0), (1, 0)];
        assert_eq!(
            drain(BatchEdgeSource::new(2, &dup_tail).with_dedup(), 1),
            vec![(0, 1)]
        );
    }

    #[test]
    fn batch_source_rejects_out_of_bound_ids() {
        let edges = [(0u32, 5u32)];
        let mut s = BatchEdgeSource::new(3, &edges);
        let mut chunk = Vec::new();
        assert!(s.next_chunk(&mut chunk, 10).is_err());
    }

    #[test]
    fn text_source_matches_eager_reader() {
        let el = erdos_renyi::edges(200, 500, 11);
        let path = tmp("stream_eq.txt");
        edgelist_txt::write_file(&path, &el).unwrap();
        let s = TextEdgeSource::open(&path).unwrap();
        assert_eq!(s.vertex_bound(), 200);
        let streamed = drain(s, 37);
        let eager = edgelist_txt::read_file(&path).unwrap();
        assert_eq!(streamed, eager.edges);
    }

    #[test]
    fn text_source_without_header_prescans_bound() {
        let path = tmp("stream_nohdr.txt");
        std::fs::write(&path, "0 1\n5 2\n# comment\n3 7\n").unwrap();
        let s = TextEdgeSource::open(&path).unwrap();
        assert_eq!(s.vertex_bound(), 8);
        assert_eq!(drain(s, 2), vec![(0, 1), (5, 2), (3, 7)]);
    }

    #[test]
    fn mtx_source_matches_eager_reader() {
        let el = erdos_renyi::edges(150, 400, 5);
        let path = tmp("stream_eq.mtx");
        let mut buf = Vec::new();
        mtx::write(&mut buf, &el).unwrap();
        std::fs::write(&path, &buf).unwrap();
        let s = MtxEdgeSource::open(&path).unwrap();
        assert_eq!(s.edge_hint(), Some(400));
        let streamed = drain(s, 64);
        let eager = mtx::read_file(&path).unwrap();
        assert_eq!(streamed, eager.edges);
        assert_eq!(streamed.len(), 400);
    }

    #[test]
    fn mtx_source_detects_truncation() {
        let path = tmp("stream_trunc.mtx");
        std::fs::write(
            &path,
            "%%MatrixMarket matrix coordinate pattern general\n3 3 2\n1 2\n",
        )
        .unwrap();
        let mut s = MtxEdgeSource::open(&path).unwrap();
        let mut chunk = Vec::new();
        assert!(s.next_chunk(&mut chunk, 16).is_err());
    }

    #[test]
    fn skg_source_streams_every_slot_in_csr_order() {
        let g = rmat::generate(&GenConfig { scale: 9, avg_degree: 6, seed: 4 });
        let path = tmp("stream_eq.skg");
        binary::write_file(&path, &g).unwrap();
        let s = SkgEdgeSource::open(&path).unwrap();
        assert_eq!(s.vertex_bound(), g.num_vertices());
        assert_eq!(s.edge_hint(), Some(g.num_edge_slots() as u64));
        let streamed = drain(s, 101);
        let eager: Vec<_> = g.iter_edges().collect();
        assert_eq!(streamed, eager);
    }

    #[test]
    fn skg_source_handles_empty_and_isolated_vertices() {
        let g = CsrGraph::from_parts(vec![0, 0, 2, 2, 2], vec![2, 3]).unwrap();
        let path = tmp("stream_iso.skg");
        binary::write_file(&path, &g).unwrap();
        let s = SkgEdgeSource::open(&path).unwrap();
        assert_eq!(drain(s, 1), vec![(1, 2), (1, 3)]);
        let empty = CsrGraph::from_parts(vec![0], vec![]).unwrap();
        let path = tmp("stream_empty.skg");
        binary::write_file(&path, &empty).unwrap();
        let s = SkgEdgeSource::open(&path).unwrap();
        assert!(drain(s, 8).is_empty());
    }

    #[test]
    fn skg_source_rejects_bad_magic() {
        let path = tmp("stream_bad.skg");
        std::fs::write(&path, b"NOTMAGIC\x00\x00\x00\x00\x00\x00\x00\x00").unwrap();
        assert!(SkgEdgeSource::open(&path).is_err());
    }

    #[test]
    fn synthetic_er_matches_materialized_generator() {
        let el = erdos_renyi::edges(300, 1000, 42);
        let s = SyntheticEdgeSource::erdos_renyi(300, 1000, 42);
        assert_eq!(drain(s, 128), el.edges);
    }

    #[test]
    fn synthetic_rmat_matches_materialized_generator() {
        let cfg = GenConfig { scale: 8, avg_degree: 4, seed: 9 };
        let el = rmat::edges_with_probs(&cfg, crate::graph::gen::rmat::GRAPH500_PROBS);
        let s = SyntheticEdgeSource::rmat(&cfg);
        assert_eq!(s.vertex_bound(), 256);
        assert_eq!(drain(s, 333), el.edges);
    }

    #[test]
    fn csr_adapter_equals_iter_edges() {
        let g = rmat::generate(&GenConfig { scale: 8, avg_degree: 5, seed: 3 });
        let s = CsrEdgeSource::new(&g);
        let streamed = drain(s, 77);
        let eager: Vec<_> = g.iter_edges().collect();
        assert_eq!(streamed, eager);
    }

    #[test]
    fn collect_edges_roundtrip() {
        let edges: Vec<(VertexId, VertexId)> = vec![(0, 1), (2, 3), (1, 2)];
        let el = collect_edges(BatchEdgeSource::new(4, &edges)).unwrap();
        assert_eq!(el.num_vertices, 4);
        assert_eq!(el.edges, edges);
    }

    #[test]
    fn open_path_dispatches_by_extension() {
        let el = erdos_renyi::edges(50, 100, 2);
        let txt = tmp("dispatch.txt");
        edgelist_txt::write_file(&txt, &el).unwrap();
        assert_eq!(open_path(&txt).unwrap().vertex_bound(), 50);
        assert!(open_path("graph.unknown").is_err());
    }
}
