//! Synthetic graph generators — the scaled analogues of the paper's dataset
//! suite (DESIGN.md §3). Every generator is seeded and deterministic.
//!
//! | Paper graph | Type   | Analogue here |
//! |-------------|--------|---------------|
//! | twitter10   | Social | [`barabasi_albert`] (preferential attachment) |
//! | g500        | Synth  | [`rmat`] with Graph500 parameters |
//! | msa10       | Bio    | [`knn_overlap`] (sequence-similarity window) |
//! | clueweb12 / wdc14 / eu15 / wdc12 | Web | [`hostweb`] (host-block locality + power-law cross links) |

pub mod barabasi_albert;
pub mod erdos_renyi;
pub mod grid;
pub mod hostweb;
pub mod knn_overlap;
pub mod rmat;
pub mod simple;
pub mod watts_strogatz;

/// Common knobs for the scale-style generators.
#[derive(Clone, Copy, Debug)]
pub struct GenConfig {
    /// log2 of the vertex count (Graph500 convention).
    pub scale: u32,
    /// Average (undirected) degree target.
    pub avg_degree: u32,
    /// Generator seed (deterministic output).
    pub seed: u64,
}

impl GenConfig {
    /// `2^scale` vertices.
    pub fn num_vertices(&self) -> usize {
        1usize << self.scale
    }

    /// Target edge count (`vertices × avg_degree`).
    pub fn num_edges(&self) -> usize {
        self.num_vertices() * self.avg_degree as usize
    }
}
