//! Web-graph analogue (clueweb12′ / wdc14′ / eu15′ / wdc12′): vertices are
//! grouped into contiguous "host" blocks (web crawls order URLs by host, so
//! consecutive IDs are densely interlinked) plus power-law cross-host links.
//! This reproduces the high-locality structure the paper's scheduler
//! analysis (§V-B) discusses for web graphs.

use crate::graph::builder::{build, BuildOptions};
use crate::graph::{CsrGraph, EdgeList};
use crate::util::rng::Xoshiro256pp;
use crate::VertexId;

#[derive(Clone, Copy, Debug)]
/// Web-graph generator knobs: host blocks with dense intra-host locality
/// plus power-law cross-host links.
pub struct HostWebConfig {
    /// Number of host blocks.
    pub num_hosts: usize,
    /// Pages per host block.
    pub vertices_per_host: usize,
    /// Intra-host edges per vertex (locality component).
    pub intra_degree: u32,
    /// Cross-host edges per vertex (power-law target hosts).
    pub inter_degree: u32,
    /// Generator seed.
    pub seed: u64,
}

/// Host-web edge list per the config.
pub fn edges(cfg: &HostWebConfig) -> EdgeList {
    let n = cfg.num_hosts * cfg.vertices_per_host;
    let mut rng = Xoshiro256pp::new(cfg.seed);
    let mut el = EdgeList::new(n);
    // Zipf-ish host popularity: host h sampled with weight 1/(h+1) via
    // inverse-CDF on precomputed cumulative weights.
    let mut cum = Vec::with_capacity(cfg.num_hosts);
    let mut acc = 0.0f64;
    for h in 0..cfg.num_hosts {
        acc += 1.0 / (h + 1) as f64;
        cum.push(acc);
    }
    let total = acc;
    let sample_host = |rng: &mut Xoshiro256pp| -> usize {
        let x = rng.next_f64() * total;
        cum.partition_point(|&c| c < x).min(cfg.num_hosts - 1)
    };
    for v in 0..n {
        let host = v / cfg.vertices_per_host;
        let host_base = host * cfg.vertices_per_host;
        // intra-host: nearby IDs (dense local neighborhoods)
        for _ in 0..cfg.intra_degree {
            let u = host_base + rng.next_usize(cfg.vertices_per_host);
            el.push(v as VertexId, u as VertexId);
        }
        // inter-host: popular hosts attract links
        for _ in 0..cfg.inter_degree {
            let th = sample_host(&mut rng);
            let u = th * cfg.vertices_per_host + rng.next_usize(cfg.vertices_per_host);
            el.push(v as VertexId, u as VertexId);
        }
    }
    el
}

/// Generate and build the CSR in one step.
pub fn generate(cfg: &HostWebConfig) -> CsrGraph {
    build(&edges(cfg), BuildOptions::default())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> HostWebConfig {
        HostWebConfig {
            num_hosts: 32,
            vertices_per_host: 64,
            intra_degree: 6,
            inter_degree: 2,
            seed: 13,
        }
    }

    #[test]
    fn deterministic() {
        assert_eq!(generate(&cfg()), generate(&cfg()));
    }

    #[test]
    fn locality_dominates() {
        let c = cfg();
        let g = generate(&c);
        // most neighbors of a vertex are in its own host block
        let mut intra = 0usize;
        let mut total = 0usize;
        for v in 0..g.num_vertices() as VertexId {
            let host = v as usize / c.vertices_per_host;
            for &u in g.neighbors(v) {
                total += 1;
                if u as usize / c.vertices_per_host == host {
                    intra += 1;
                }
            }
        }
        assert!(intra as f64 > 0.5 * total as f64, "intra {intra}/{total}");
    }

    #[test]
    fn popular_hosts_have_more_inlinks() {
        let c = cfg();
        let g = generate(&c);
        let host_degree = |h: usize| -> usize {
            (h * c.vertices_per_host..(h + 1) * c.vertices_per_host)
                .map(|v| g.degree(v as VertexId))
                .sum()
        };
        // first host (most popular) should beat the last by a wide margin
        assert!(host_degree(0) > 2 * host_degree(c.num_hosts - 1));
    }
}
