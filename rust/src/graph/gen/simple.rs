//! Elementary graphs used by unit/property tests and matching stress cases:
//! paths, cycles, stars, complete graphs, random bipartite graphs, and a
//! "perfect matching plus noise" construction with known optimum.

use crate::graph::builder::{build, BuildOptions};
use crate::graph::{CsrGraph, EdgeList};
use crate::util::rng::Xoshiro256pp;
use crate::VertexId;

/// Path graph `0-1-…-(n-1)`.
pub fn path(n: usize) -> CsrGraph {
    let mut el = EdgeList::new(n);
    for v in 1..n {
        el.push((v - 1) as VertexId, v as VertexId);
    }
    build(&el, BuildOptions::default())
}

/// Cycle on `n ≥ 3` vertices.
pub fn cycle(n: usize) -> CsrGraph {
    assert!(n >= 3);
    let mut el = EdgeList::new(n);
    for v in 0..n {
        el.push(v as VertexId, ((v + 1) % n) as VertexId);
    }
    build(&el, BuildOptions::default())
}

/// Star K_{1,n-1}: center 0. Any maximal matching has exactly one edge —
/// the worst case for contention on a single vertex.
pub fn star(n: usize) -> CsrGraph {
    assert!(n >= 2);
    let mut el = EdgeList::new(n);
    for v in 1..n {
        el.push(0, v as VertexId);
    }
    build(&el, BuildOptions::default())
}

/// Complete graph K_n.
pub fn complete(n: usize) -> CsrGraph {
    let mut el = EdgeList::new(n);
    for u in 0..n {
        for v in (u + 1)..n {
            el.push(u as VertexId, v as VertexId);
        }
    }
    build(&el, BuildOptions::default())
}

/// Random bipartite graph: `left`+`right` vertices, `m` uniform cross edges.
pub fn bipartite_random(left: usize, right: usize, m: usize, seed: u64) -> CsrGraph {
    let mut rng = Xoshiro256pp::new(seed);
    let mut el = EdgeList::new(left + right);
    for _ in 0..m {
        let u = rng.next_usize(left) as VertexId;
        let v = (left + rng.next_usize(right)) as VertexId;
        el.push(u, v);
    }
    build(&el, BuildOptions::default())
}

/// A graph containing a planted perfect matching (2i, 2i+1) plus `noise`
/// random extra edges. Any maximal matching must contain at least n/4 edges
/// and the planted matching shows the achievable optimum (n/2).
pub fn planted_matching(n_pairs: usize, noise: usize, seed: u64) -> CsrGraph {
    let n = 2 * n_pairs;
    let mut rng = Xoshiro256pp::new(seed);
    let mut el = EdgeList::new(n);
    for i in 0..n_pairs {
        el.push((2 * i) as VertexId, (2 * i + 1) as VertexId);
    }
    for _ in 0..noise {
        el.push(rng.next_usize(n) as VertexId, rng.next_usize(n) as VertexId);
    }
    build(&el, BuildOptions::default())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn path_degrees() {
        let g = path(5);
        assert_eq!(g.degree(0), 1);
        assert_eq!(g.degree(2), 2);
        assert_eq!(g.num_undirected_edges(), 4);
    }

    #[test]
    fn cycle_is_2_regular() {
        let g = cycle(7);
        for v in 0..7 {
            assert_eq!(g.degree(v), 2);
        }
    }

    #[test]
    fn star_shape() {
        let g = star(10);
        assert_eq!(g.degree(0), 9);
        for v in 1..10 {
            assert_eq!(g.degree(v), 1);
        }
    }

    #[test]
    fn complete_count() {
        let g = complete(6);
        assert_eq!(g.num_undirected_edges(), 15);
    }

    #[test]
    fn bipartite_has_no_same_side_edges() {
        let g = bipartite_random(50, 70, 300, 3);
        for (v, u) in g.iter_edges() {
            let (a, b) = (v < 50, u < 50);
            assert_ne!(a, b, "edge ({v},{u}) inside one side");
        }
    }

    #[test]
    fn planted_matching_contains_pairs() {
        let g = planted_matching(20, 30, 5);
        for i in 0..20u32 {
            assert!(g.neighbors(2 * i).contains(&(2 * i + 1)));
        }
    }
}
