//! RMAT / Graph500-style recursive-matrix generator (Murphy et al., "the
//! graph 500"). Produces the skewed-degree synthetic analogue of `g500`.

use super::GenConfig;
use crate::graph::builder::{build, BuildOptions};
use crate::graph::{CsrGraph, EdgeList};
use crate::util::rng::Xoshiro256pp;
use crate::VertexId;

/// Graph500 default partition probabilities.
pub const GRAPH500_PROBS: (f64, f64, f64, f64) = (0.57, 0.19, 0.19, 0.05);

/// Sample one RMAT edge by recursive quadrant descent. Exposed so the
/// streaming [`crate::graph::stream::SyntheticEdgeSource`] can generate
/// edges on the fly without materializing an edge list.
#[inline]
pub fn sample_edge(
    rng: &mut Xoshiro256pp,
    scale: u32,
    probs: (f64, f64, f64, f64),
) -> (VertexId, VertexId) {
    let (a, b, c, _d) = probs;
    let (mut u, mut v) = (0usize, 0usize);
    for level in (0..scale).rev() {
        let r = rng.next_f64();
        let bit = 1usize << level;
        if r < a {
            // upper-left: nothing
        } else if r < a + b {
            v |= bit;
        } else if r < a + b + c {
            u |= bit;
        } else {
            u |= bit;
            v |= bit;
        }
    }
    (u as VertexId, v as VertexId)
}

/// Generate an RMAT edge list with the given quadrant probabilities.
pub fn edges_with_probs(cfg: &GenConfig, probs: (f64, f64, f64, f64)) -> EdgeList {
    let n = cfg.num_vertices();
    let m = cfg.num_edges();
    let mut rng = Xoshiro256pp::new(cfg.seed);
    let mut el = EdgeList::new(n);
    for _ in 0..m {
        let (u, v) = sample_edge(&mut rng, cfg.scale, probs);
        el.push(u, v);
    }
    el
}

/// Generate a symmetric, deduplicated CSR graph with Graph500 probabilities.
pub fn generate(cfg: &GenConfig) -> CsrGraph {
    build(&edges_with_probs(cfg, GRAPH500_PROBS), BuildOptions::default())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let cfg = GenConfig { scale: 8, avg_degree: 4, seed: 1 };
        assert_eq!(generate(&cfg), generate(&cfg));
    }

    #[test]
    fn different_seeds_differ() {
        let a = generate(&GenConfig { scale: 8, avg_degree: 4, seed: 1 });
        let b = generate(&GenConfig { scale: 8, avg_degree: 4, seed: 2 });
        assert_ne!(a, b);
    }

    #[test]
    fn sizes_in_expected_range() {
        let cfg = GenConfig { scale: 10, avg_degree: 8, seed: 7 };
        let g = generate(&cfg);
        assert_eq!(g.num_vertices(), 1024);
        // dedup + self-loop removal shrinks below m, but not to nothing
        assert!(g.num_undirected_edges() > cfg.num_edges() / 4);
        assert!(g.num_undirected_edges() <= cfg.num_edges());
        assert!(g.is_symmetric());
    }

    #[test]
    fn rmat_is_skewed() {
        // RMAT should produce a heavier max degree than Erdos-Renyi of the
        // same size (degree skew drives the paper's conflict analysis).
        let g = generate(&GenConfig { scale: 12, avg_degree: 8, seed: 3 });
        let (_, med, max, _) = g.degree_summary();
        assert!(max > 8 * med.max(1), "max {max} med {med}");
    }
}
