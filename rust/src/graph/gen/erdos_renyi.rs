//! Erdős–Rényi G(n, m): m uniform random edges. The "no locality" extreme
//! of the suite (paper §V-B: randomization minimizes JIT conflicts).

use crate::graph::builder::{build, BuildOptions};
use crate::graph::{CsrGraph, EdgeList};
use crate::util::rng::Xoshiro256pp;
use crate::VertexId;

/// `m` uniform random pairs over `0..n` (duplicates/self-loops allowed;
/// the builder normalizes).
pub fn edges(n: usize, m: usize, seed: u64) -> EdgeList {
    let mut rng = Xoshiro256pp::new(seed);
    let mut el = EdgeList::new(n);
    for _ in 0..m {
        let u = rng.next_usize(n) as VertexId;
        let v = rng.next_usize(n) as VertexId;
        el.push(u, v);
    }
    el
}

/// Generate and build the CSR in one step.
pub fn generate(n: usize, m: usize, seed: u64) -> CsrGraph {
    build(&edges(n, m, seed), BuildOptions::default())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(generate(500, 2000, 9), generate(500, 2000, 9));
    }

    #[test]
    fn edge_count_near_m() {
        let g = generate(1000, 4000, 5);
        // collisions + self loops remove only a few for sparse graphs
        assert!(g.num_undirected_edges() > 3800);
        assert!(g.num_undirected_edges() <= 4000);
        assert!(g.is_symmetric());
    }

    #[test]
    fn degrees_are_concentrated() {
        let g = generate(1 << 12, 8 << 12, 11);
        let (_, med, max, mean) = g.degree_summary();
        assert!((mean - 16.0).abs() < 2.0);
        // ER max degree stays within a small factor of the median
        assert!(max < 6 * med, "max {max} med {med}");
    }
}
