//! Watts–Strogatz small-world generator: a ring lattice (high locality)
//! with probability-`beta` rewiring (injected randomness). Used by the
//! scheduler ablations to sweep the locality spectrum the paper's §V-B
//! analysis covers — `beta=0` is the pure-locality extreme, `beta=1`
//! approaches Erdős–Rényi.

use crate::graph::builder::{build, BuildOptions};
use crate::graph::{CsrGraph, EdgeList};
use crate::util::rng::Xoshiro256pp;
use crate::VertexId;

#[derive(Clone, Copy, Debug)]
/// Watts–Strogatz small-world generator knobs.
pub struct WsConfig {
    /// Vertices on the ring.
    pub n: usize,
    /// Each vertex connects to `k` nearest neighbors on each side (ring).
    pub k: usize,
    /// Rewiring probability.
    pub beta: f64,
    /// Generator seed.
    pub seed: u64,
}

/// Small-world edge list per the config.
pub fn edges(cfg: &WsConfig) -> EdgeList {
    assert!(cfg.n > 2 * cfg.k, "n must exceed 2k");
    let mut rng = Xoshiro256pp::new(cfg.seed);
    let mut el = EdgeList::new(cfg.n);
    for v in 0..cfg.n {
        for j in 1..=cfg.k {
            let mut u = (v + j) % cfg.n;
            if rng.next_f64() < cfg.beta {
                // rewire to a uniform random endpoint (avoid v itself)
                u = rng.next_usize(cfg.n);
                if u == v {
                    u = (u + 1) % cfg.n;
                }
            }
            el.push(v as VertexId, u as VertexId);
        }
    }
    el
}

/// Generate and build the CSR in one step.
pub fn generate(cfg: &WsConfig) -> CsrGraph {
    build(&edges(cfg), BuildOptions::default())
}

/// Fraction of edges whose endpoints are within `k` ring positions — a
/// locality score in [0, 1].
pub fn locality_score(g: &CsrGraph, k: usize) -> f64 {
    let n = g.num_vertices() as i64;
    let mut near = 0usize;
    let mut total = 0usize;
    for (v, u) in g.iter_edges() {
        total += 1;
        let d = (v as i64 - u as i64).rem_euclid(n).min((u as i64 - v as i64).rem_euclid(n));
        if d <= k as i64 {
            near += 1;
        }
    }
    if total == 0 {
        0.0
    } else {
        near as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let c = WsConfig { n: 500, k: 3, beta: 0.1, seed: 4 };
        assert_eq!(generate(&c), generate(&c));
    }

    #[test]
    fn beta_zero_is_pure_ring() {
        let c = WsConfig { n: 200, k: 2, beta: 0.0, seed: 1 };
        let g = generate(&c);
        assert!((locality_score(&g, 2) - 1.0).abs() < 1e-12);
        for v in 0..200u32 {
            assert_eq!(g.degree(v), 4, "vertex {v}");
        }
    }

    #[test]
    fn beta_sweep_decreases_locality() {
        let mk = |beta| {
            locality_score(
                &generate(&WsConfig { n: 2000, k: 4, beta, seed: 9 }),
                4,
            )
        };
        let l0 = mk(0.0);
        let l_half = mk(0.5);
        let l1 = mk(1.0);
        assert!(l0 > l_half && l_half > l1, "{l0} {l_half} {l1}");
        assert!(l1 < 0.2);
    }

    #[test]
    fn matching_works_across_the_sweep() {
        use crate::matching::{skipper::Skipper, verify, MaximalMatcher};
        for beta in [0.0, 0.3, 1.0] {
            let g = generate(&WsConfig { n: 1000, k: 3, beta, seed: 11 });
            let m = Skipper::new(4).run(&g);
            verify::check(&g, &m).unwrap();
        }
    }
}
