//! Barabási–Albert preferential attachment — the social-network analogue
//! (twitter10′): heavy-tailed degrees, hub-centric conflicts.

use crate::graph::builder::{build, BuildOptions};
use crate::graph::{CsrGraph, EdgeList};
use crate::util::rng::Xoshiro256pp;
use crate::VertexId;

/// `n` vertices, each new vertex attaching `m_per_vertex` edges to existing
/// vertices chosen proportional to degree (implemented with the standard
/// repeated-endpoint trick: sample uniformly from the endpoint list).
pub fn edges(n: usize, m_per_vertex: usize, seed: u64) -> EdgeList {
    assert!(n >= 2 && m_per_vertex >= 1);
    let mut rng = Xoshiro256pp::new(seed);
    let mut el = EdgeList::new(n);
    // endpoint multiset: each occurrence ∝ degree
    let mut endpoints: Vec<VertexId> = vec![0, 1];
    el.push(0, 1);
    for v in 2..n {
        for _ in 0..m_per_vertex.min(v) {
            let t = endpoints[rng.next_usize(endpoints.len())];
            if t != v as VertexId {
                el.push(v as VertexId, t);
                endpoints.push(v as VertexId);
                endpoints.push(t);
            }
        }
    }
    el
}

/// Generate and build the CSR in one step.
pub fn generate(n: usize, m_per_vertex: usize, seed: u64) -> CsrGraph {
    build(&edges(n, m_per_vertex, seed), BuildOptions::default())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(generate(300, 3, 4), generate(300, 3, 4));
    }

    #[test]
    fn heavy_tail() {
        let g = generate(4096, 4, 8);
        let (_, med, max, _) = g.degree_summary();
        assert!(max > 10 * med.max(1), "expected hubs: max {max} med {med}");
        assert!(g.is_symmetric());
    }

    #[test]
    fn connected_enough() {
        // every vertex beyond the first two attaches at least once w.h.p.
        let g = generate(1000, 2, 6);
        let isolated = (0..1000).filter(|&v| g.degree(v) == 0).count();
        assert!(isolated < 5, "isolated={isolated}");
    }
}
