//! Sequence-similarity analogue (msa10′, the MS-BioGraphs stand-in):
//! vertex i connects to `k` random vertices within a sliding window
//! `[i-window, i+window]` — similarity graphs over sorted sequences link
//! near-identical (nearby) sequences, giving banded, medium-locality
//! structure with occasional long-range matches.

use crate::graph::builder::{build, BuildOptions};
use crate::graph::{CsrGraph, EdgeList};
use crate::util::rng::Xoshiro256pp;
use crate::VertexId;

#[derive(Clone, Copy, Debug)]
/// Banded k-NN overlap generator knobs (the msa10 analogue: sequence-
/// similarity links inside a sliding window).
pub struct KnnConfig {
    /// Vertices.
    pub n: usize,
    /// Links per vertex.
    pub k: u32,
    /// Similarity window width.
    pub window: usize,
    /// Probability that a link escapes the window (long-range similarity).
    pub long_range_p: f64,
    /// Generator seed.
    pub seed: u64,
}

/// Banded k-NN edge list per the config.
pub fn edges(cfg: &KnnConfig) -> EdgeList {
    let mut rng = Xoshiro256pp::new(cfg.seed);
    let mut el = EdgeList::new(cfg.n);
    for v in 0..cfg.n {
        for _ in 0..cfg.k {
            let u = if rng.next_f64() < cfg.long_range_p {
                rng.next_usize(cfg.n)
            } else {
                let lo = v.saturating_sub(cfg.window);
                let hi = (v + cfg.window + 1).min(cfg.n);
                lo + rng.next_usize(hi - lo)
            };
            el.push(v as VertexId, u as VertexId);
        }
    }
    el
}

/// Generate and build the CSR in one step.
pub fn generate(cfg: &KnnConfig) -> CsrGraph {
    build(&edges(cfg), BuildOptions::default())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> KnnConfig {
        KnnConfig {
            n: 2000,
            k: 8,
            window: 16,
            long_range_p: 0.05,
            seed: 21,
        }
    }

    #[test]
    fn deterministic() {
        assert_eq!(generate(&cfg()), generate(&cfg()));
    }

    #[test]
    fn banded_structure() {
        let c = cfg();
        let g = generate(&c);
        let mut near = 0usize;
        let mut total = 0usize;
        for v in 0..g.num_vertices() as VertexId {
            for &u in g.neighbors(v) {
                total += 1;
                if (u as i64 - v as i64).unsigned_abs() as usize <= c.window {
                    near += 1;
                }
            }
        }
        assert!(near as f64 > 0.85 * total as f64, "near {near}/{total}");
    }

    #[test]
    fn expected_density() {
        let c = cfg();
        let g = generate(&c);
        let (_, _, _, mean) = g.degree_summary();
        // ~2k per vertex before dedup; window overlaps dedup some
        assert!(mean > c.k as f64 * 0.8, "mean {mean}");
    }
}
