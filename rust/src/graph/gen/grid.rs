//! 2-D grid / torus — the maximum-locality extreme: consecutive vertex IDs
//! are connected, stressing the thread-dispersed scheduler's claim that
//! high-locality inputs also see few JIT conflicts (paper §V-B).

use crate::graph::builder::{build, BuildOptions};
use crate::graph::{CsrGraph, EdgeList};
use crate::VertexId;

/// Grid edge list; `torus` adds wrap-around links on both axes.
pub fn edges(rows: usize, cols: usize, torus: bool) -> EdgeList {
    let n = rows * cols;
    let mut el = EdgeList::new(n);
    let id = |r: usize, c: usize| (r * cols + c) as VertexId;
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                el.push(id(r, c), id(r, c + 1));
            } else if torus && cols > 2 {
                el.push(id(r, c), id(r, 0));
            }
            if r + 1 < rows {
                el.push(id(r, c), id(r + 1, c));
            } else if torus && rows > 2 {
                el.push(id(r, c), id(0, c));
            }
        }
    }
    el
}

/// Generate and build the CSR in one step.
pub fn generate(rows: usize, cols: usize, torus: bool) -> CsrGraph {
    build(&edges(rows, cols, torus), BuildOptions::default())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_edge_count() {
        // rows*(cols-1) + cols*(rows-1)
        let g = generate(5, 7, false);
        assert_eq!(g.num_undirected_edges(), 5 * 6 + 7 * 4);
        assert!(g.is_symmetric());
    }

    #[test]
    fn torus_regular_degree() {
        let g = generate(8, 8, true);
        for v in 0..64 {
            assert_eq!(g.degree(v), 4, "vertex {v}");
        }
    }

    #[test]
    fn locality_structure() {
        // interior vertices neighbor v±1 and v±cols
        let g = generate(10, 10, false);
        let v = 55u32;
        assert_eq!(g.neighbors(v), &[45, 54, 56, 65]);
    }
}
