//! Edge-list → CSR conversion: counting-sort construction, optional
//! symmetrization, duplicate/self-loop filtering, and sorted neighbor lists.

use super::{CsrGraph, EdgeList};
use crate::{EdgeIdx, VertexId};

/// Conversion options.
#[derive(Clone, Copy, Debug)]
pub struct BuildOptions {
    /// Store each undirected edge in both endpoints' lists.
    pub symmetrize: bool,
    /// Drop duplicate edges (after symmetrization).
    pub dedup: bool,
    /// Drop self-loops. Skipper skips them at run time (Alg. 1 lines 6–7),
    /// but the EMS baselines expect clean input.
    pub drop_self_loops: bool,
}

impl Default for BuildOptions {
    fn default() -> Self {
        Self {
            symmetrize: true,
            dedup: true,
            drop_self_loops: true,
        }
    }
}

/// Build a CSR graph from an edge list via counting sort.
pub fn build(el: &EdgeList, opts: BuildOptions) -> CsrGraph {
    let n = el.num_vertices;
    let mut degree = vec![0u64; n + 1];
    let mut count_edge = |u: VertexId, v: VertexId| {
        if opts.drop_self_loops && u == v {
            return;
        }
        degree[u as usize + 1] += 1;
        if opts.symmetrize && u != v {
            degree[v as usize + 1] += 1;
        }
    };
    for &(u, v) in &el.edges {
        count_edge(u, v);
    }
    // prefix sum -> offsets
    let mut offsets: Vec<EdgeIdx> = degree;
    for i in 1..offsets.len() {
        offsets[i] += offsets[i - 1];
    }
    let total = *offsets.last().unwrap() as usize;
    let mut cursor = offsets.clone();
    let mut neighbors = vec![0 as VertexId; total];
    for &(u, v) in &el.edges {
        if opts.drop_self_loops && u == v {
            continue;
        }
        neighbors[cursor[u as usize] as usize] = v;
        cursor[u as usize] += 1;
        if opts.symmetrize && u != v {
            neighbors[cursor[v as usize] as usize] = u;
            cursor[v as usize] += 1;
        }
    }
    // sort each neighbor list (small lists; unstable sort is fine)
    for v in 0..n {
        let (s, e) = (offsets[v] as usize, offsets[v + 1] as usize);
        neighbors[s..e].sort_unstable();
    }
    let g = CsrGraph::from_parts(offsets, neighbors).expect("builder produced valid CSR");
    if opts.dedup {
        dedup_sorted(&g)
    } else {
        g
    }
}

/// Remove duplicate entries from sorted neighbor lists.
fn dedup_sorted(g: &CsrGraph) -> CsrGraph {
    let n = g.num_vertices();
    let mut offsets: Vec<EdgeIdx> = Vec::with_capacity(n + 1);
    let mut neighbors: Vec<VertexId> = Vec::with_capacity(g.num_edge_slots());
    offsets.push(0);
    for v in 0..n as VertexId {
        let mut prev: Option<VertexId> = None;
        for &u in g.neighbors(v) {
            if prev != Some(u) {
                neighbors.push(u);
                prev = Some(u);
            }
        }
        offsets.push(neighbors.len() as EdgeIdx);
    }
    CsrGraph::from_parts(offsets, neighbors).expect("dedup produced valid CSR")
}

/// Convert a CSR graph back into a (u <= v canonical) edge list.
pub fn to_edge_list(g: &CsrGraph) -> EdgeList {
    let mut el = EdgeList::new(g.num_vertices());
    for (v, u) in g.iter_edges() {
        if v <= u {
            el.push(v, u);
        }
    }
    el
}

/// Relabel vertices by the given permutation (`perm[old] = new`), preserving
/// topology. Used to test ordering-independence of the algorithms (the paper
/// processes graphs "using their published vertex ordering").
pub fn relabel(g: &CsrGraph, perm: &[VertexId]) -> CsrGraph {
    assert_eq!(perm.len(), g.num_vertices());
    let mut el = EdgeList::new(g.num_vertices());
    for (v, u) in g.iter_edges() {
        if v <= u {
            el.push(perm[v as usize], perm[u as usize]);
        }
    }
    build(
        &el,
        BuildOptions {
            symmetrize: true,
            dedup: false,
            drop_self_loops: false,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_symmetric_sorted_csr() {
        let mut el = EdgeList::new(4);
        el.push(2, 0);
        el.push(0, 1);
        el.push(3, 2);
        el.push(1, 2);
        let g = build(&el, BuildOptions::default());
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_undirected_edges(), 4);
        assert!(g.is_symmetric());
        assert_eq!(g.neighbors(2), &[0, 1, 3]);
    }

    #[test]
    fn drops_self_loops_and_dups() {
        let mut el = EdgeList::new(3);
        el.push(0, 0); // self loop
        el.push(0, 1);
        el.push(1, 0); // duplicate after symmetrization
        el.push(1, 2);
        let g = build(&el, BuildOptions::default());
        assert_eq!(g.neighbors(0), &[1]);
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert_eq!(g.num_undirected_edges(), 2);
    }

    #[test]
    fn keeps_self_loops_when_asked() {
        let mut el = EdgeList::new(2);
        el.push(0, 0);
        el.push(0, 1);
        let g = build(
            &el,
            BuildOptions {
                drop_self_loops: false,
                ..Default::default()
            },
        );
        assert_eq!(g.neighbors(0), &[0, 1]);
    }

    #[test]
    fn directed_build_when_not_symmetrized() {
        let mut el = EdgeList::new(3);
        el.push(0, 1);
        el.push(1, 2);
        let g = build(
            &el,
            BuildOptions {
                symmetrize: false,
                ..Default::default()
            },
        );
        assert_eq!(g.neighbors(0), &[1]);
        assert_eq!(g.neighbors(1), &[2]);
        assert!(g.neighbors(2).is_empty());
        assert!(!g.is_symmetric());
    }

    #[test]
    fn roundtrip_edge_list() {
        let mut el = EdgeList::new(5);
        el.push(0, 1);
        el.push(1, 2);
        el.push(3, 4);
        let g = build(&el, BuildOptions::default());
        let back = to_edge_list(&g);
        let mut edges = back.edges.clone();
        edges.sort_unstable();
        assert_eq!(edges, vec![(0, 1), (1, 2), (3, 4)]);
    }

    #[test]
    fn relabel_preserves_topology() {
        let mut el = EdgeList::new(4);
        el.push(0, 1);
        el.push(2, 3);
        let g = build(&el, BuildOptions::default());
        // swap 0<->3
        let g2 = relabel(&g, &[3, 1, 2, 0]);
        assert_eq!(g2.num_undirected_edges(), 2);
        assert_eq!(g2.neighbors(3), &[1]);
        assert_eq!(g2.neighbors(0), &[2]);
        assert!(g2.is_symmetric());
    }
}
