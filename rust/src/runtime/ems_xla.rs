//! The XLA-backed EMS matcher: compiles an AOT HLO artifact on the PJRT CPU
//! client and runs the tensorized EMS matching (L2 model + L1 Pallas
//! kernel) from rust. This is the cross-layer baseline the benches compare
//! Skipper against (DESIGN.md §5, "xla-ems").
//!
//! Follows /opt/xla-example/load_hlo: HLO *text* → `HloModuleProto` →
//! `XlaComputation` → `client.compile` → `execute`. Lowered with
//! `return_tuple=True`, so results unwrap via `to_tuple3`.

use super::manifest::{ArtifactEntry, Manifest};
use crate::graph::CsrGraph;
use crate::matching::ems::canonical_edges;
use crate::matching::{MaximalMatcher, Matching};
use crate::VertexId;
use anyhow::{anyhow, Context, Result};

/// One compiled (V, E) variant.
pub struct EmsExecutable {
    exe: xla::PjRtLoadedExecutable,
    /// Compiled vertex capacity of the variant.
    pub num_vertices: usize,
    /// Compiled edge capacity of the variant.
    pub num_edges: usize,
}

impl EmsExecutable {
    /// Compile one HLO artifact on the PJRT client.
    pub fn load(client: &xla::PjRtClient, path: &str, entry: &ArtifactEntry) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parse HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("PJRT compile {path}"))?;
        Ok(Self {
            exe,
            num_vertices: entry.vertices,
            num_edges: entry.edges,
        })
    }

    /// Execute on padded edge arrays. Returns `(match_flag, matched, rounds)`.
    pub fn run_padded(
        &self,
        edge_u: &[i32],
        edge_v: &[i32],
        valid: &[i32],
    ) -> Result<(Vec<i32>, Vec<i32>, i32)> {
        if edge_u.len() != self.num_edges
            || edge_v.len() != self.num_edges
            || valid.len() != self.num_edges
        {
            return Err(anyhow!(
                "padded inputs must have length {}, got {}/{}/{}",
                self.num_edges,
                edge_u.len(),
                edge_v.len(),
                valid.len()
            ));
        }
        let lu = xla::Literal::vec1(edge_u);
        let lv = xla::Literal::vec1(edge_v);
        let lw = xla::Literal::vec1(valid);
        let result = self.exe.execute::<xla::Literal>(&[lu, lv, lw])?[0][0]
            .to_literal_sync()?;
        let (flag, matched, rounds) = result.to_tuple3()?;
        Ok((
            flag.to_vec::<i32>()?,
            matched.to_vec::<i32>()?,
            rounds.get_first_element::<i32>()?,
        ))
    }

    /// Match a graph: extract canonical edges, pad, execute, unpad.
    /// Returns `(matching, rounds)`.
    pub fn run_graph(&self, g: &CsrGraph) -> Result<(Matching, i32)> {
        let edges = canonical_edges(g);
        if g.num_vertices() > self.num_vertices || edges.len() > self.num_edges {
            return Err(anyhow!(
                "graph (V={}, E={}) exceeds variant (V={}, E={})",
                g.num_vertices(),
                edges.len(),
                self.num_vertices,
                self.num_edges
            ));
        }
        let mut eu = vec![0i32; self.num_edges];
        let mut ev = vec![0i32; self.num_edges];
        let mut valid = vec![0i32; self.num_edges];
        for (i, &(u, v)) in edges.iter().enumerate() {
            eu[i] = u as i32;
            ev[i] = v as i32;
            valid[i] = 1;
        }
        let (flag, _matched, rounds) = self.run_padded(&eu, &ev, &valid)?;
        let pairs: Vec<(VertexId, VertexId)> = edges
            .iter()
            .enumerate()
            .filter(|&(i, _)| flag[i] != 0)
            .map(|(_, &e)| e)
            .collect();
        Ok((Matching::from_pairs(pairs), rounds))
    }
}

/// Baseline matcher that picks the smallest fitting artifact variant per
/// graph. Compiled executables are cached per variant.
pub struct XlaEmsMatcher {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: std::sync::Mutex<std::collections::BTreeMap<(usize, usize), std::sync::Arc<EmsExecutable>>>,
}

impl XlaEmsMatcher {
    /// Load from the default artifacts directory (`SKIPPER_ARTIFACTS` or
    /// `artifacts/`).
    pub fn from_default_artifacts() -> Result<Self> {
        Self::from_dir(&super::artifacts_dir())
    }

    /// Load from an explicit artifacts directory.
    pub fn from_dir(dir: &str) -> Result<Self> {
        let manifest = Manifest::load(dir).map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self {
            client,
            manifest,
            cache: std::sync::Mutex::new(std::collections::BTreeMap::new()),
        })
    }

    /// Compiled shape variants listed in the manifest.
    pub fn variants(&self) -> &[ArtifactEntry] {
        &self.manifest.artifacts
    }

    /// Get (compiling if needed) the executable for a graph of this size.
    pub fn executable_for(&self, v: usize, e: usize) -> Result<std::sync::Arc<EmsExecutable>> {
        let entry = self
            .manifest
            .smallest_fitting(v, e)
            .ok_or_else(|| anyhow!("no artifact variant fits V={v}, E={e}"))?
            .clone();
        let key = (entry.vertices, entry.edges);
        let mut cache = self.cache.lock().unwrap();
        if let Some(exe) = cache.get(&key) {
            return Ok(exe.clone());
        }
        let exe = std::sync::Arc::new(EmsExecutable::load(
            &self.client,
            &self.manifest.full_path(&entry),
            &entry,
        )?);
        cache.insert(key, exe.clone());
        Ok(exe)
    }

    /// Match `g` through the best-fitting compiled variant; returns the
    /// matching and the device-reported round count.
    pub fn match_graph(&self, g: &CsrGraph) -> Result<(Matching, i32)> {
        let edges = canonical_edges(g).len();
        let exe = self.executable_for(g.num_vertices(), edges)?;
        exe.run_graph(g)
    }
}

impl MaximalMatcher for XlaEmsMatcher {
    fn name(&self) -> String {
        "XLA-EMS".into()
    }

    fn run(&self, g: &CsrGraph) -> Matching {
        self.match_graph(g)
            .expect("XLA EMS execution failed (are artifacts built?)")
            .0
    }
}
