//! Artifact manifest (`artifacts/manifest.toml`): the contract between
//! `python/compile/aot.py` and the rust runtime. One `[[artifact]]` entry
//! per (V, E) shape variant.

use crate::util::tomlite::Document;

#[derive(Clone, Debug, PartialEq, Eq)]
/// One compiled (V, E) shape variant listed in the manifest.
pub struct ArtifactEntry {
    /// HLO artifact path (relative to the manifest).
    pub path: String,
    /// Compiled vertex capacity.
    pub vertices: usize,
    /// Compiled edge capacity.
    pub edges: usize,
}

#[derive(Clone, Debug, Default)]
/// Parsed artifact manifest.
pub struct Manifest {
    /// All compiled variants, as listed.
    pub artifacts: Vec<ArtifactEntry>,
    /// Directory the entries' paths are relative to.
    pub base_dir: String,
}

impl Manifest {
    /// Parse manifest text; paths stay relative to `base_dir`.
    pub fn parse(text: &str, base_dir: &str) -> Result<Self, String> {
        let doc = Document::parse(text)?;
        let mut artifacts = Vec::new();
        for t in doc.table_arrays.get("artifact").map(|v| v.as_slice()).unwrap_or(&[]) {
            let path = t
                .get("path")
                .and_then(|v| v.as_str())
                .ok_or("artifact missing path")?
                .to_string();
            let vertices = t
                .get("vertices")
                .and_then(|v| v.as_int())
                .ok_or("artifact missing vertices")? as usize;
            let edges = t
                .get("edges")
                .and_then(|v| v.as_int())
                .ok_or("artifact missing edges")? as usize;
            artifacts.push(ArtifactEntry { path, vertices, edges });
        }
        if artifacts.is_empty() {
            return Err("manifest contains no [[artifact] ] entries".into());
        }
        Ok(Self {
            artifacts,
            base_dir: base_dir.to_string(),
        })
    }

    /// Load `<dir>/manifest.toml`.
    pub fn load(dir: &str) -> Result<Self, String> {
        let path = format!("{dir}/manifest.toml");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {path}: {e} (run `make artifacts` first)"))?;
        Self::parse(&text, dir)
    }

    /// The smallest variant that fits a graph with `v` vertices and `e`
    /// canonical edges.
    pub fn smallest_fitting(&self, v: usize, e: usize) -> Option<&ArtifactEntry> {
        self.artifacts
            .iter()
            .filter(|a| a.vertices >= v && a.edges >= e)
            .min_by_key(|a| (a.vertices, a.edges))
    }

    /// Absolute-ish path of one entry (base dir + relative path).
    pub fn full_path(&self, entry: &ArtifactEntry) -> String {
        format!("{}/{}", self.base_dir, entry.path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# AOT artifact manifest
[[artifact]]
path = "ems_v256_e1024.hlo.txt"
vertices = 256
edges = 1024

[[artifact]]
path = "ems_v1024_e4096.hlo.txt"
vertices = 1024
edges = 4096
"#;

    #[test]
    fn parses_entries() {
        let m = Manifest::parse(SAMPLE, "arts").unwrap();
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.artifacts[0].vertices, 256);
        assert_eq!(m.full_path(&m.artifacts[1]), "arts/ems_v1024_e4096.hlo.txt");
    }

    #[test]
    fn smallest_fitting_selects_correctly() {
        let m = Manifest::parse(SAMPLE, ".").unwrap();
        assert_eq!(m.smallest_fitting(100, 500).unwrap().vertices, 256);
        assert_eq!(m.smallest_fitting(256, 1024).unwrap().vertices, 256);
        assert_eq!(m.smallest_fitting(300, 500).unwrap().vertices, 1024);
        assert!(m.smallest_fitting(5000, 1).is_none());
    }

    #[test]
    fn rejects_empty_and_malformed() {
        assert!(Manifest::parse("", ".").is_err());
        assert!(Manifest::parse("[[artifact]]\npath = \"x\"\n", ".").is_err());
    }
}
