//! Offline stub for the PJRT/XLA runtime (compiled when the `xla` cargo
//! feature is off, which is the default in the network-less sandbox).
//!
//! Mirrors the public API of `ems_xla.rs` exactly; every entry point
//! returns an error so callers fall through to their artifact-missing skip
//! paths. Enable the `xla` feature (and add the `xla` + `anyhow`
//! dependencies) to compile the real PJRT-backed implementation.

use super::manifest::ArtifactEntry;
use crate::graph::CsrGraph;
use crate::matching::{MaximalMatcher, Matching};

const UNAVAILABLE: &str =
    "XLA runtime not compiled in (build with `--features xla` and the xla/anyhow deps)";

/// Stub of one compiled (V, E) variant. Never instantiated.
pub struct EmsExecutable {
    /// Compiled vertex capacity of the variant.
    pub num_vertices: usize,
    /// Compiled edge capacity of the variant.
    pub num_edges: usize,
}

impl EmsExecutable {
    /// Execute on padded edge arrays. Always errors in the stub.
    pub fn run_padded(
        &self,
        _edge_u: &[i32],
        _edge_v: &[i32],
        _valid: &[i32],
    ) -> Result<(Vec<i32>, Vec<i32>, i32), String> {
        Err(UNAVAILABLE.into())
    }

    /// Match a graph. Always errors in the stub.
    pub fn run_graph(&self, _g: &CsrGraph) -> Result<(Matching, i32), String> {
        Err(UNAVAILABLE.into())
    }
}

/// Stub matcher: construction always fails, so the instance methods are
/// unreachable but keep the real signatures for the call sites.
pub struct XlaEmsMatcher {
    variants: Vec<ArtifactEntry>,
}

impl XlaEmsMatcher {
    /// Always errors in the stub (no XLA runtime compiled in).
    pub fn from_default_artifacts() -> Result<Self, String> {
        Err(UNAVAILABLE.into())
    }

    /// Always errors in the stub.
    pub fn from_dir(_dir: &str) -> Result<Self, String> {
        Err(UNAVAILABLE.into())
    }

    /// Compiled shape variants (unreachable: construction always fails).
    pub fn variants(&self) -> &[ArtifactEntry] {
        &self.variants
    }

    /// Always errors in the stub.
    pub fn executable_for(
        &self,
        _v: usize,
        _e: usize,
    ) -> Result<std::sync::Arc<EmsExecutable>, String> {
        Err(UNAVAILABLE.into())
    }

    /// Always errors in the stub.
    pub fn match_graph(&self, _g: &CsrGraph) -> Result<(Matching, i32), String> {
        Err(UNAVAILABLE.into())
    }
}

impl MaximalMatcher for XlaEmsMatcher {
    fn name(&self) -> String {
        "XLA-EMS".into()
    }

    fn run(&self, g: &CsrGraph) -> Matching {
        self.match_graph(g)
            .expect("XLA EMS execution failed (are artifacts built?)")
            .0
    }
}
