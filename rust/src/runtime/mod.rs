//! PJRT runtime: loads the AOT-compiled L1/L2 EMS matcher
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and exposes it
//! as a [`crate::matching::MaximalMatcher`] baseline callable from the L3
//! hot path. Python never runs at request time — the HLO text is compiled
//! by the in-process PJRT CPU client and executed directly.

#[cfg(feature = "xla")]
pub mod ems_xla;
#[cfg(not(feature = "xla"))]
#[path = "ems_stub.rs"]
pub mod ems_xla;
pub mod manifest;

pub use ems_xla::{EmsExecutable, XlaEmsMatcher};
pub use manifest::{ArtifactEntry, Manifest};

/// Default artifacts directory, overridable via `SKIPPER_ARTIFACTS`.
pub fn artifacts_dir() -> String {
    std::env::var("SKIPPER_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}
