//! `skipper-cli` — launcher for the Skipper reproduction.
//!
//! Subcommands:
//!   gen         generate a suite dataset (or any built-in generator) to disk
//!   run         run a matching algorithm on a graph and report stats; with
//!               --stream, match while edges stream in (no CSR materialized)
//!   experiment  regenerate one paper table/figure (table1, table2, fig3,
//!               fig7, fig8, fig9, fig10, fig11, stream, dynamic, xla-ems)
//!   suite       run every experiment and write reports/
//!   serve       long-running match service (stdin pipe or TCP): INSERT/
//!               DELETE/QUERY/STATS/EPOCH over the fully dynamic engine
//!   churn       insert/delete churn driver over the dynamic engine with
//!               per-epoch maximality verification and repair telemetry
//!   report      perf-trajectory registry: render committed BENCH_*.json
//!               files as markdown, publish a recorded run, or gate a
//!               candidate run against the last committed baseline
//!   lint        validate observability artifacts offline: a Prometheus
//!               metrics dump (a METRICS scrape or --metrics-file) and/or
//!               a Chrome trace JSON (--trace-out), with --require
//!               span-name assertions and --require-exemplars
//!               exemplar/span cross-reference checks — the CI smoke gate
//!   dash        render every committed BENCH_*.json trajectory (plus an
//!               optional live metrics snapshot) as one dependency-free
//!               static HTML dashboard — inline SVG sparklines, no JS
//!   info        print dataset/suite information

use skipper::apram::{simulate_skipper, SimConfig};
use skipper::coordinator::calibrate::calibrate;
use skipper::coordinator::config::RunConfig;
use skipper::coordinator::datasets::{
    cache_path, generate_cached, generate_cached_path, spec_by_name, Scale, SUITE,
};
use skipper::coordinator::experiments as exp;
use skipper::coordinator::report::Report;
use skipper::graph::io::{binary, edgelist_txt, mtx};
use skipper::graph::builder::{build, BuildOptions};
use skipper::graph::CsrGraph;
use skipper::matching::ems::auer_bisseling::AuerBisseling;
use skipper::matching::ems::birn::Birn;
use skipper::matching::ems::idmm::Idmm;
use skipper::matching::ems::israeli_itai::IsraeliItai;
use skipper::matching::ems::pbmm::Pbmm;
use skipper::matching::ems::sidmm::Sidmm;
use skipper::matching::sgmm::Sgmm;
use skipper::matching::skipper::Skipper;
use skipper::matching::streaming::{StreamingSkipper, DEFAULT_CHUNK_EDGES};
use skipper::matching::{verify, MaximalMatcher};
use skipper::coordinator::dash::{render_dash, LiveSource};
use skipper::coordinator::registry::{self, BenchRecord, Registry};
use skipper::obs::{metrics, trace};
use skipper::dynamic::churn::{run_churn, ChurnConfig, ChurnGen};
use skipper::dynamic::AdjLayout;
use skipper::service::{
    serve_follower_lines, serve_follower_tcp, serve_lines, serve_tcp, ServiceConfig,
};
use skipper::util::cli::Args;
use std::path::Path;
use std::time::Instant;

const USAGE: &str = "\
skipper-cli — Skipper maximal matching (paper reproduction)

USAGE:
  skipper-cli gen --dataset <name> [--scale tiny|small|medium|large] [--out g.skg]
  skipper-cli run --graph <file|dataset> [--algo skipper|sgmm|sidmm|idmm|pbmm|israeli-itai|birn|auer-bisseling|xla-ems]
              [--threads N] [--scale S] [--verify] [--conflicts] [--sim]
              [--record FILE]  (write the run as a perf-registry candidate
               record for `skipper-cli report`: graph shape as exact_*
               metrics, wall time and edge throughput as gated metrics)
  skipper-cli run --graph <file|dataset> --stream [--threads N] [--chunk-edges N] [--verify]
              (match while edges stream off disk — no CSR is materialized;
               reports peak topology-resident bytes vs the CSR equivalent)
  skipper-cli experiment <id> [--config cfg.toml] [--scale S]   (ids: table1 table2 fig3 fig7 fig8 fig9 fig10 fig11 stream dynamic scale durability xla-ems)
  skipper-cli suite [--config cfg.toml] [--scale S]
  skipper-cli serve [--vertices N] [--threads N] [--tcp HOST:PORT]
              [--engine-shards P] [--no-pool] [--no-pipeline] [--shards N]
              [--shard-capacity N] [--epoch-max-updates N]
              [--epoch-max-requests N] [--data-dir DIR] [--no-wal]
              [--fsync] [--snapshot-every E] [--debug-commands]
              [--trace] [--trace-out FILE] [--metrics-file FILE]
              [--metrics-addr HOST:PORT] [--pin none|compact|spread] [--numa]
              [--replicate-addr HOST:PORT] [--follow HOST:PORT]
              (line protocol INSERT/DELETE/QUERY/STATS[ full]/SNAPSHOT/
               EPOCH/QUIT/SHUTDOWN, specified in docs/PROTOCOL.md; stdin
               pipe by default, concurrent clients with --tcp.
               --engine-shards P (default 1) partitions the engine's
               vertices so every epoch's mutate phase runs P-way parallel
               on a persistent shard-worker pool; --no-pool forks scoped
               threads per epoch instead (the measured baseline). The
               coordinator pipelines by default — epoch N+1's updates are
               parsed/routed while epoch N is applied on a flusher thread;
               --no-pipeline runs flushes inline on the router. Coalescing:
               queued updates flush as one epoch at an EPOCH barrier, or
               once --epoch-max-updates (default 8192) accumulate;
               --epoch-max-requests (default 256) caps requests drained per
               router round. STATS returns cheap counters; STATS full adds
               the O(|V|+|E|) maximality audit.
               Durability: --data-dir DIR makes the service crash-safe —
               every epoch's update batch is logged to a CRC-checked WAL
               before it is applied (--fsync forces each record to media;
               --no-wal disables logging), SNAPSHOT/--snapshot-every E
               write binary snapshots in the background, SHUTDOWN/EOF
               drain and write a final snapshot, and the next boot
               recovers: newest valid snapshot + WAL replay, verified
               maximal before going live. --debug-commands enables the
               CRASH fault-injection command for recovery testing and the
               BLACKBOX command (dump a post-mortem metrics+trace artifact
               into --data-dir on demand); a router/flusher panic writes
               the same blackbox-<ts>.json artifact automatically.
               Observability: the METRICS command returns a Prometheus
               text scrape and TRACE [n] one Chrome-trace JSON line, both
               specified in docs/PROTOCOL.md. --trace turns span recording
               on from boot (off by default — one relaxed atomic load when
               off); --trace-out FILE writes every recorded span as Chrome
               trace-event JSON at exit and implies --trace;
               --metrics-file FILE writes the final Prometheus exposition
               at exit, identical to a last METRICS scrape;
               --metrics-addr HOST:PORT serves live scrapes over HTTP
               (GET /metrics — point Prometheus at it).
               Replication: --replicate-addr HOST:PORT makes this server a
               primary that streams every committed epoch's WAL record to
               followers over TCP; --follow HOST:PORT starts a warm standby
               that replays that stream through its own engine (same
               --vertices and --engine-shards as the primary), answers
               QUERY/STATS/METRICS read-only, and becomes a writable
               primary on PROMOTE — e.g. after kill -9 of the old primary.
               A follower with its own --data-dir WAL-logs each shipped
               epoch before applying it and recovers+resumes on restart.
               Framing and the replica_* STATS fields are specified in
               docs/PROTOCOL.md.
               Topology: --pin compact packs the P shard workers onto the
               cores of as few NUMA nodes as possible, --pin spread
               round-robins them across nodes; either way each worker pins
               itself before first-touching its shard's adjacency arena and
               partner[] stripe, so shard memory is socket-local, and block
               slabs are advised MADV_HUGEPAGE. --numa is shorthand for
               --pin compact. Single-node hosts degrade gracefully —
               placement changes timings only, never results)
  skipper-cli churn [--gen rmat|er|ba|grid] [--scale LOG2_V] [--avg-degree D]
              [--epochs E] [--batch B] [--delete-frac F] [--threads N]
              [--engine-shards P] [--no-pool] [--warmup-epochs W] [--seed S]
              [--layout flat|blocked|blocked<N>] [--block-bytes N]
              [--pin none|compact|spread] [--numa]
              [--no-verify] [--save FILE] [--load FILE] [--record FILE]
              [--trace-out FILE] [--metrics-file FILE]
              (mixed insert/delete epochs over the dynamic engine; verifies
               maximality over the LIVE edge set after every epoch and
               reports spawn-vs-run mutate timings — --no-pool selects the
               forked per-epoch baseline for comparison. --layout picks the
               adjacency sidecar storage: flat per-vertex vectors, or the
               cache-line block arena (default blocked64; blocked<N> or
               --block-bytes N sets the block size, a multiple of 64 in
               64..=4096). --save FILE writes the warmed engine state as a
               snapshot at the end; --load FILE restores one instead of
               running warmup, so a warmed-up workload restarts instantly.
               --pin pins shard workers to cores (see serve) so each
               shard's arena and partner[] stripe are first-touched
               socket-local; --numa = --pin compact.
               --record FILE writes the run's machine manifest, config, and
               metrics as a candidate record for `skipper-cli report`.
               --trace-out FILE enables span recording for the run and
               writes the collected spans as Chrome trace-event JSON —
               open in chrome://tracing or `lint --trace` it.
               --metrics-file FILE writes the end-of-run Prometheus
               exposition of the process-global registry, identical to a
               final METRICS scrape of the same instruments)
  skipper-cli report [--dir BENCH] [--publish FILE | --gate FILE [--threshold T]]
              (the committed perf-trajectory registry, BENCH_<bench>.json
               under --dir. With no action: render every registry as a
               markdown trajectory report. --publish appends a candidate
               record — from `churn --record` or a bench — to its registry.
               --gate compares a candidate against the last committed run of
               the same config hash and exits non-zero on regression beyond
               --threshold (default 0.15): exact_* metrics must match
               bit-for-bit even across machines, wall-clock metrics gate
               strictly only when the machine manifests match and warn
               otherwise, and an unseen config passes as a seeding run)
  skipper-cli lint [--metrics FILE] [--trace FILE] [--require a,b,c]
              [--require-exemplars fam1,fam2]
              (validate observability artifacts offline and exit non-zero
               on any violation — the CI smoke gate. --metrics checks a
               Prometheus text-format dump (a captured METRICS scrape or a
               serve --metrics-file) for syntactic validity, exemplar
               syntax included; --trace checks a Chrome trace-event JSON
               file (serve/churn --trace-out); --require fails unless
               every comma-separated span name appears in the trace;
               --require-exemplars fails unless every listed histogram
               family carries at least one exemplar in --metrics, and —
               when --trace rides along — unless every exemplar span_id
               resolves to a span in the trace (no dangling ids))
  skipper-cli dash [--dir BENCH] [--out dash.html]
              [--metrics FILE | --metrics-addr HOST:PORT]
              (render the committed perf-trajectory registries as one
               self-contained static HTML dashboard: per-metric SVG
               sparklines of every BENCH_*.json run series, colored per
               config hash, with the report --gate ±threshold band drawn
               around the newest committed value. No JavaScript, no
               external assets — the file is safe to open anywhere.
               --metrics FILE appends a live-snapshot section from a saved
               exposition; --metrics-addr scrapes GET /metrics once from a
               running serve --metrics-addr endpoint instead. Histogram
               exemplars in the snapshot are listed with their span ids)
  skipper-cli info
";

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(
        raw,
        &[
            "verify",
            "conflicts",
            "sim",
            "stream",
            "no-verify",
            "no-pool",
            "no-pipeline",
            "no-wal",
            "fsync",
            "debug-commands",
            "trace",
            "numa",
            "help",
        ],
    ) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    if args.flag("help") || args.positional.is_empty() {
        println!("{USAGE}");
        return;
    }
    let cmd = args.positional[0].as_str();
    let result = match cmd {
        "gen" => cmd_gen(&args),
        "run" => cmd_run(&args),
        "experiment" => cmd_experiment(&args),
        "suite" => cmd_suite(&args),
        "serve" => cmd_serve(&args),
        "churn" => cmd_churn(&args),
        "report" => cmd_report(&args),
        "lint" => cmd_lint(&args),
        "dash" => cmd_dash(&args),
        "info" => cmd_info(),
        other => Err(format!("unknown command {other:?}\n{USAGE}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn load_config(args: &Args) -> Result<RunConfig, String> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::load(path)?,
        None => RunConfig::default(),
    };
    if let Some(s) = args.get("scale") {
        cfg.scale = Scale::parse(s)?;
    }
    if let Some(t) = args.get("threads") {
        cfg.threads = t.parse().map_err(|_| format!("bad --threads {t:?}"))?;
    }
    Ok(cfg)
}

/// Load a graph: a suite dataset name, or an .skg/.mtx/.txt file.
fn load_graph(name: &str, scale: Scale, cache_dir: &str) -> Result<CsrGraph, String> {
    if let Some(spec) = spec_by_name(name) {
        return Ok(generate_cached(spec, scale, cache_dir));
    }
    if name.ends_with(".skg") {
        return binary::read_file(name);
    }
    if name.ends_with(".mtx") {
        let el = mtx::read_file(name)?;
        return Ok(build(&el, BuildOptions::default()));
    }
    if name.ends_with(".txt") || name.ends_with(".el") {
        let el = edgelist_txt::read_file(name)?;
        return Ok(build(&el, BuildOptions::default()));
    }
    Err(format!(
        "unknown graph {name:?} (suite dataset or .skg/.mtx/.txt file)"
    ))
}

fn cmd_gen(args: &Args) -> Result<(), String> {
    let name = args.get("dataset").ok_or("--dataset required")?;
    let scale = Scale::parse(args.get_or("scale", "small"))?;
    let spec = spec_by_name(name).ok_or_else(|| format!("unknown dataset {name:?}"))?;
    let g = generate_cached(spec, scale, args.get_or("cache-dir", "data"));
    let out = args
        .get("out")
        .map(String::from)
        .unwrap_or_else(|| format!("data/{}_{}.skg", spec.name, scale.name()));
    binary::write_file(&out, &g)?;
    println!(
        "{}: |V|={} |E|={} (slots {}) max_deg={} -> {out}",
        spec.name,
        g.num_vertices(),
        g.num_undirected_edges(),
        g.num_edge_slots(),
        g.max_degree()
    );
    Ok(())
}

fn cmd_run(args: &Args) -> Result<(), String> {
    let cfg = load_config(args)?;
    let graph_name = args.get("graph").ok_or("--graph required")?;
    let threads: usize = args.get_parse("threads", 4usize)?;
    if args.get("record").is_some() && (args.flag("sim") || args.flag("stream")) {
        return Err("--record applies to the static run path (drop --sim/--stream)".into());
    }
    if args.flag("stream") {
        return cmd_run_stream(args, &cfg, graph_name, threads);
    }
    let g = load_graph(graph_name, cfg.scale, &cfg.cache_dir)?;
    let algo = args.get_or("algo", "skipper");
    println!(
        "graph {graph_name}: |V|={} |E|={} slots={}",
        g.num_vertices(),
        g.num_undirected_edges(),
        g.num_edge_slots()
    );

    if args.flag("sim") {
        // APRAM virtual-thread simulation instead of real threads
        let t0 = Instant::now();
        let rep = simulate_skipper(&g, &SimConfig::new(threads));
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "apram-sim skipper t={threads}: |M|={} makespan_ops={} total_ops={} steals={} ({dt:.3}s host)",
            rep.matching.len(),
            rep.makespan_ops(),
            rep.total_ops(),
            rep.steals
        );
        println!("conflicts: {}", rep.conflicts.table_row());
        if args.flag("verify") {
            verify::check(&g, &rep.matching)?;
            println!("verify: OK");
        }
        return Ok(());
    }

    let t0 = Instant::now();
    let (matching, conflict_row): (_, Option<String>) = match algo {
        "skipper" => {
            let sk = Skipper::new(threads);
            if args.flag("conflicts") {
                let rep = sk.run_with_conflicts(&g);
                (rep.matching, Some(rep.conflicts.table_row()))
            } else {
                (sk.run(&g), None)
            }
        }
        "sgmm" => (Sgmm.run(&g), None),
        "sidmm" => (Sidmm::default().run(&g), None),
        "idmm" => (Idmm::default().run(&g), None),
        "pbmm" => (Pbmm::default().run(&g), None),
        "israeli-itai" => (IsraeliItai::default().run(&g), None),
        "birn" => (Birn::default().run(&g), None),
        "auer-bisseling" => (AuerBisseling::default().run(&g), None),
        "xla-ems" => {
            let m = skipper::runtime::XlaEmsMatcher::from_default_artifacts()
                .map_err(|e| format!("{e:#}"))?;
            let (matching, rounds) = m.match_graph(&g).map_err(|e| format!("{e:#}"))?;
            println!("xla-ems rounds: {rounds}");
            (matching, None)
        }
        other => return Err(format!("unknown --algo {other:?}")),
    };
    let dt = t0.elapsed().as_secs_f64();
    println!("{algo}: |M|={} in {dt:.4}s", matching.len());
    if let Some(row) = conflict_row {
        println!("conflicts: {row}");
    }
    if args.flag("verify") {
        verify::check(&g, &matching)?;
        println!("verify: OK (valid maximal matching)");
    }
    if let Some(path) = args.get("record") {
        // graph shape is deterministic (exact_*); matching size is
        // schedule-dependent for the parallel matchers, so it rides along
        // as an advisory metric (reported, never gated)
        let graph_tag: String = graph_name
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
            .collect();
        let mut config = std::collections::BTreeMap::new();
        config.insert("workload".to_string(), "run".to_string());
        config.insert("algo".to_string(), algo.to_string());
        config.insert("graph".to_string(), graph_name.to_string());
        config.insert("scale".to_string(), cfg.scale.name().to_string());
        config.insert("threads".to_string(), threads.to_string());
        let mut met = std::collections::BTreeMap::new();
        met.insert("exact_vertices".to_string(), g.num_vertices() as f64);
        met.insert("exact_edges".to_string(), g.num_undirected_edges() as f64);
        met.insert("run_wall_s".to_string(), dt);
        met.insert(
            "edges_per_s".to_string(),
            g.num_undirected_edges() as f64 / dt.max(1e-9),
        );
        met.insert("matched_pairs".to_string(), matching.len() as f64);
        let rec = BenchRecord::new(format!("run_{algo}_{graph_tag}"), config, met);
        rec.write_file(Path::new(path))?;
        println!(
            "recorded bench {} (config {}) -> {path}; publish or gate it with `skipper-cli report`",
            rec.bench,
            rec.config_hash()
        );
    }
    Ok(())
}

/// Streaming ingest→match: the matching is computed chunk-by-chunk as edges
/// come off disk (or out of the dataset cache); no CSR is ever built for
/// matching. `--verify` materializes the union graph *afterwards*, for
/// checking only.
fn cmd_run_stream(
    args: &Args,
    cfg: &RunConfig,
    graph_name: &str,
    threads: usize,
) -> Result<(), String> {
    let algo = args.get_or("algo", "skipper");
    if algo != "skipper" {
        return Err(format!("--stream supports --algo skipper only (got {algo:?})"));
    }
    let chunk_edges: usize = args.get_parse("chunk-edges", DEFAULT_CHUNK_EDGES)?;

    // Resolve the stream path: suite dataset names stream from their .skg
    // cache (generated once if missing), files stream directly.
    let path = if let Some(spec) = spec_by_name(graph_name) {
        let cached = cache_path(spec, cfg.scale, &cfg.cache_dir);
        if !std::path::Path::new(&cached).exists() {
            eprintln!("cache miss: generating {cached} once; the run streams it back off disk");
            let (_, path) = generate_cached_path(spec, cfg.scale, &cfg.cache_dir)?;
            path
        } else {
            cached
        }
    } else {
        graph_name.to_string()
    };

    let source = skipper::graph::stream::open_path(&path)?;
    let sk = StreamingSkipper::new(threads).with_chunk_edges(chunk_edges);
    let t0 = Instant::now();
    let rep = sk.run(source)?;
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "stream skipper t={threads} chunk={chunk_edges}: |M|={} over {} streamed edges ({} chunks) in {dt:.4}s ({:.2} Medges/s)",
        rep.matching.len(),
        rep.edges_streamed,
        rep.chunks,
        rep.edges_streamed as f64 / dt.max(1e-9) / 1e6
    );
    println!("conflicts: {}", rep.conflicts.table_row());
    let stream_b = rep.peak_topology_bytes();
    let csr_b = rep.csr_equivalent_bytes();
    println!(
        "peak topology-resident: {stream_b} B (state {} B + chunk buffers {} B) vs CSR-equivalent {csr_b} B — {:.1}x smaller",
        rep.state_bytes,
        rep.chunk_buffer_bytes,
        csr_b as f64 / stream_b.max(1) as f64
    );
    if args.flag("verify") {
        let g = load_graph(&path, cfg.scale, &cfg.cache_dir)?;
        verify::check(&g, &rep.matching)?;
        println!("verify: OK (valid maximal matching; union graph materialized for checking only)");
    }
    Ok(())
}

fn run_experiments(ids: &[&str], cfg: &RunConfig) -> Result<(), String> {
    let needs_metrics = ids.iter().any(|&id| {
        id != "xla-ems" && id != "stream" && id != "dynamic" && id != "scale" && id != "durability"
    });
    let mut report = Report::new();
    let metrics;
    let cost;
    if needs_metrics {
        eprintln!("calibrating cost model...");
        cost = calibrate();
        eprintln!(
            "cost model: {:.2} ns/access, {:.0} ns L3-miss penalty",
            cost.ns_per_access, cost.l3_miss_penalty_ns
        );
        eprintln!(
            "collecting suite metrics (scale={}, table2_runs={})...",
            cfg.scale.name(),
            cfg.table2_runs
        );
        let all = exp::collect_suite(cfg.scale, &cfg.cache_dir, cfg.table2_runs);
        metrics = if cfg.datasets.is_empty() {
            all
        } else {
            all.into_iter()
                .filter(|m| {
                    cfg.datasets
                        .iter()
                        .any(|d| d == m.spec.name || d == m.spec.paper_name)
                })
                .collect()
        };
    } else {
        metrics = Vec::new();
        cost = Default::default();
    }
    for &id in ids {
        let content = match id {
            "table1" => exp::table1(&metrics, &cost),
            "table2" => exp::table2(&metrics),
            "fig3" => exp::fig3(&metrics, &cost),
            "fig7" => exp::fig7(&metrics),
            "fig8" => exp::fig8(&metrics),
            "fig9" => exp::fig9(&metrics, &cost),
            "fig10" => exp::fig10(&metrics, &cost),
            "fig11" => exp::fig11(&metrics),
            "stream" => {
                // real threads (unlike the simulated cfg.threads elsewhere):
                // honor the config but never oversubscribe the host
                let host = std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(4);
                exp::stream_vs_csr(cfg.scale, &cfg.cache_dir, cfg.threads.min(host))?
            }
            "dynamic" => {
                let host = std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(4);
                exp::dynamic_churn(cfg.scale, cfg.threads.min(host))?
            }
            "scale" => {
                let host = std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(4);
                exp::shard_scale(cfg.scale, cfg.threads.min(host))?
            }
            "durability" => {
                let host = std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(4);
                exp::durability(cfg.scale, cfg.threads.min(host))?
            }
            // artifact-dependent: inside a multi-experiment run, skip (with
            // the reason in the report) rather than sinking the whole suite;
            // an explicit `experiment xla-ems` still fails loudly
            "xla-ems" => match exp::xla_ems(&cfg.cache_dir) {
                Ok(content) => content,
                Err(e) if ids.len() > 1 => format!("xla-ems SKIPPED: {e}\n"),
                Err(e) => return Err(e),
            },
            other => return Err(format!("unknown experiment {other:?}")),
        };
        println!("{content}");
        report.add(id, content);
    }
    let files = report.write_dir(&cfg.report_dir)?;
    eprintln!("wrote {}", files.join(", "));
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<(), String> {
    let id = args
        .positional
        .get(1)
        .ok_or("experiment id required (table1 table2 fig3 fig7 fig8 fig9 fig10 fig11 stream dynamic scale durability xla-ems)")?;
    let cfg = load_config(args)?;
    run_experiments(&[id.as_str()], &cfg)
}

fn cmd_suite(args: &Args) -> Result<(), String> {
    let cfg = load_config(args)?;
    run_experiments(
        &[
            "table1", "table2", "fig3", "fig7", "fig8", "fig9", "fig10", "fig11", "stream",
            "dynamic", "scale", "durability", "xla-ems",
        ],
        &cfg,
    )
}

/// `--pin none|compact|spread` with `--numa` as shorthand for `--pin
/// compact` (an explicit `--pin` wins when both are given).
fn parse_pin(args: &Args) -> Result<skipper::dynamic::PinPolicy, String> {
    use skipper::dynamic::PinPolicy;
    match args.get("pin") {
        Some(s) => PinPolicy::parse(s),
        None if args.flag("numa") => Ok(PinPolicy::Compact),
        None => Ok(PinPolicy::None),
    }
}

/// Long-running match service: stdin pipe by default (one client — the CI
/// smoke path and anything scriptable), or `--tcp HOST:PORT` for concurrent
/// clients, each on its own connection thread and queue shard.
fn cmd_serve(args: &Args) -> Result<(), String> {
    let defaults = ServiceConfig::default();
    let cfg = ServiceConfig {
        num_vertices: args.get_parse("vertices", defaults.num_vertices)?,
        threads: args.get_parse("threads", defaults.threads)?,
        engine_shards: args.get_parse("engine-shards", defaults.engine_shards)?,
        pool: !args.flag("no-pool"),
        pipeline: !args.flag("no-pipeline"),
        shards: args.get_parse("shards", defaults.shards)?,
        shard_capacity: args.get_parse("shard-capacity", defaults.shard_capacity)?,
        epoch_max_requests: args.get_parse("epoch-max-requests", defaults.epoch_max_requests)?,
        epoch_max_updates: args.get_parse("epoch-max-updates", defaults.epoch_max_updates)?,
        data_dir: args.get("data-dir").map(String::from),
        wal: !args.flag("no-wal"),
        wal_fsync: args.flag("fsync"),
        snapshot_every: args.get_parse("snapshot-every", defaults.snapshot_every)?,
        debug_commands: args.flag("debug-commands"),
        exit_on_panic: true,
        pin: parse_pin(args)?,
        metrics_addr: args.get("metrics-addr").map(String::from),
        replicate_addr: args.get("replicate-addr").map(String::from),
    };
    if cfg.engine_shards == 0 || cfg.epoch_max_updates == 0 || cfg.epoch_max_requests == 0 {
        return Err("--engine-shards/--epoch-max-updates/--epoch-max-requests must be >= 1".into());
    }
    if cfg.data_dir.is_none()
        && (args.flag("no-wal") || args.flag("fsync") || args.get("snapshot-every").is_some())
    {
        return Err("--no-wal/--fsync/--snapshot-every require --data-dir".into());
    }
    // P = 1 runs its single shard inline whatever the policy says
    let workers = if cfg.engine_shards == 1 {
        "inline single-shard"
    } else if cfg.pool {
        "pooled"
    } else {
        "forked"
    };
    let durability = match &cfg.data_dir {
        Some(dir) => format!(
            "; durable in {dir} (wal {}{}, snapshot-every {})",
            if cfg.wal { "on" } else { "off" },
            if cfg.wal_fsync { "+fsync" } else { "" },
            cfg.snapshot_every
        ),
        None => String::new(),
    };
    let mode = format!(
        "{workers} shard workers (pin={}), {} coordinator{durability}",
        cfg.pin.name(),
        if cfg.pipeline { "pipelined" } else { "inline" }
    );
    let trace_out = args.get("trace-out");
    if args.flag("trace") || trace_out.is_some() {
        trace::set_enabled(true);
    }
    if let Some(primary) = args.get("follow") {
        if cfg.replicate_addr.is_some() {
            return Err(
                "--follow and --replicate-addr are mutually exclusive (chained replication \
                 is not supported)"
                    .into(),
            );
        }
        if args.get("metrics-file").is_some() {
            return Err("--metrics-file is not supported with --follow (scrape METRICS)".into());
        }
        let summary = match args.get("tcp") {
            Some(addr) => serve_follower_tcp(&cfg, primary, addr, |bound| {
                eprintln!(
                    "following {primary}; serving |V|={} ({mode}) on tcp://{bound} (SHUTDOWN to stop)",
                    cfg.num_vertices
                );
            })?,
            None => {
                eprintln!(
                    "following {primary}; serving |V|={} ({mode}) on stdin (QUERY/STATS[ full]/METRICS/PROMOTE; QUIT or EOF to stop)",
                    cfg.num_vertices
                );
                let stdin = std::io::stdin();
                let mut stdout = std::io::stdout();
                serve_follower_lines(&cfg, primary, stdin.lock(), &mut stdout)?
            }
        };
        eprintln!(
            "follower replayed to epoch {}{}; final |M|={} over {} live edges, maximal={}; final snapshot at epoch {}",
            summary.epochs,
            if summary.promoted { " (promoted)" } else { "" },
            summary.matched_vertices / 2,
            summary.live_edges,
            summary.maximal,
            summary.last_snapshot_epoch,
        );
        if let Some(path) = trace_out {
            let events = trace::collect();
            let doc = trace::chrome_trace_json(&events);
            std::fs::write(path, doc.render_pretty()).map_err(|e| format!("{path}: {e}"))?;
            eprintln!("trace: {} spans -> {path} (load in chrome://tracing)", events.len());
        }
        if !summary.maximal {
            return Err("final matching failed the live-set maximality audit".into());
        }
        return Ok(());
    }
    let summary = match args.get("tcp") {
        Some(addr) => serve_tcp(&cfg, addr, |bound| {
            eprintln!(
                "serving |V|={} (P={} engine shards; {mode}) on tcp://{bound} (SHUTDOWN to stop)",
                cfg.num_vertices, cfg.engine_shards
            );
        })?,
        None => {
            eprintln!(
                "serving |V|={} (P={} engine shards; {mode}) on stdin (INSERT/DELETE/QUERY/STATS[ full]/SNAPSHOT/EPOCH; QUIT or EOF to stop)",
                cfg.num_vertices, cfg.engine_shards
            );
            let stdin = std::io::stdin();
            let mut stdout = std::io::stdout();
            serve_lines(&cfg, stdin.lock(), &mut stdout)?
        }
    };
    eprintln!(
        "served {} epochs: +{} -{} updates, repair {} edges; final |M|={} over {} live edges, maximal={}",
        summary.epochs,
        summary.total_inserts,
        summary.total_deletes,
        summary.total_repair_edges,
        summary.matched_vertices / 2,
        summary.live_edges,
        summary.maximal
    );
    if cfg.data_dir.is_some() {
        eprintln!(
            "durability: recovery replayed {} wal epochs at boot; {} epochs logged this run; final snapshot at epoch {}",
            summary.recovery_replayed, summary.wal_epochs, summary.last_snapshot_epoch
        );
    }
    // observability artifacts are written even when the final audit fails —
    // a failing run is exactly when the spans and counters matter most
    if let Some(path) = args.get("metrics-file") {
        std::fs::write(path, &summary.metrics_text).map_err(|e| format!("{path}: {e}"))?;
        eprintln!("metrics: final Prometheus exposition -> {path}");
    }
    if let Some(path) = trace_out {
        let events = trace::collect();
        let doc = trace::chrome_trace_json(&events);
        std::fs::write(path, doc.render_pretty()).map_err(|e| format!("{path}: {e}"))?;
        eprintln!("trace: {} spans -> {path} (load in chrome://tracing)", events.len());
    }
    if !summary.maximal {
        return Err("final matching failed the live-set maximality audit".into());
    }
    Ok(())
}

/// Insert/delete churn over the dynamic engine with per-epoch verification —
/// the acceptance run: `churn --gen rmat --scale 20 --delete-frac 0.5`.
fn cmd_churn(args: &Args) -> Result<(), String> {
    let scale: u32 = args.get_parse("scale", 16u32)?;
    let avg_degree: u32 = args.get_parse("avg-degree", 8u32)?;
    let gen = ChurnGen::parse(args.get_or("gen", "rmat"), scale, avg_degree)?;
    let mut layout = AdjLayout::parse(args.get_or("layout", "blocked64"))?;
    if let Some(bb) = args.get("block-bytes") {
        if layout == AdjLayout::Flat {
            return Err("--block-bytes requires --layout blocked".into());
        }
        layout = AdjLayout::parse(&format!("blocked{bb}"))?;
    }
    let cfg = ChurnConfig {
        seed: args.get_parse("seed", 1u64)?,
        threads: args.get_parse("threads", 4usize)?,
        engine_shards: args.get_parse("engine-shards", 1usize)?,
        pool: !args.flag("no-pool"),
        layout,
        pin: parse_pin(args)?,
        epochs: args.get_parse("epochs", 10usize)?,
        batch: args.get_parse("batch", 20_000usize)?,
        delete_frac: args.get_parse("delete-frac", 0.5f64)?,
        warmup_epochs: args.get_parse("warmup-epochs", 8usize)?,
        verify: !args.flag("no-verify"),
        save: args.get("save").map(String::from),
        load: args.get("load").map(String::from),
        ..ChurnConfig::new(gen)
    };
    if !(0.0..=1.0).contains(&cfg.delete_frac) {
        return Err(format!("--delete-frac {} not in [0,1]", cfg.delete_frac));
    }
    if cfg.engine_shards == 0 {
        return Err("--engine-shards must be >= 1".into());
    }
    let trace_out = args.get("trace-out");
    if trace_out.is_some() {
        trace::set_enabled(true);
        trace::clear();
    }
    println!(
        "churn {} |V|={} t={} P={} layout={} pin={} ({} shard workers): {}, then {} epochs of {} updates ({:.0}% deletes){}",
        gen.name(),
        gen.num_vertices(),
        cfg.threads,
        cfg.engine_shards,
        cfg.layout.name(),
        cfg.pin.name(),
        cfg.shard_exec().name(),
        match &cfg.load {
            Some(path) => format!("warm state loaded from {path}"),
            None => format!("{} warmup epochs", cfg.warmup_epochs),
        },
        cfg.epochs,
        cfg.batch,
        cfg.delete_frac * 100.0,
        if cfg.verify { "" } else { " [verification OFF]" }
    );
    let summary = run_churn(&cfg, |e| {
        let r = &e.report;
        let tag = if e.warmup { "warmup" } else { "epoch" };
        let verdict = match &e.verified {
            Some(Ok(())) => " verify=OK",
            Some(Err(_)) => " verify=FAIL",
            None => "",
        };
        println!(
            "{tag} {}: +{} -{} destroyed={} freed={} repair_edges={} repair_frac={:.5} |M|={} live={} conflicts={} {:.1}ms (mutate {:.2}ms = run {:.2}ms + spawn {:.3}ms){verdict}",
            r.epoch,
            r.inserts,
            r.deletes,
            r.destroyed_pairs,
            r.freed_vertices,
            r.repair_edges,
            r.repair_fraction(),
            r.matched_vertices / 2,
            r.live_edges,
            r.conflicts,
            r.wall_s * 1e3,
            r.mutate_wall_s * 1e3,
            r.mutate_run_s * 1e3,
            r.mutate_spawn_overhead_s() * 1e3,
        );
    })?;
    let p50 = skipper::util::stats::percentile(&summary.epoch_wall_s, 50.0) * 1e3;
    let p99 = skipper::util::stats::percentile(&summary.epoch_wall_s, 99.0) * 1e3;
    let mutate_p50 = skipper::util::stats::percentile(&summary.epoch_mutate_s, 50.0) * 1e3;
    let run_p50 = skipper::util::stats::percentile(&summary.epoch_mutate_run_s, 50.0) * 1e3;
    let route_p50 = skipper::util::stats::percentile(&summary.epoch_route_s, 50.0) * 1e3;
    let spawn_overhead: Vec<f64> = summary
        .epoch_mutate_s
        .iter()
        .zip(summary.epoch_mutate_run_s.iter())
        .map(|(wall, run)| (wall - run).max(0.0))
        .collect();
    let spawn_p50 = skipper::util::stats::percentile(&spawn_overhead, 50.0) * 1e3;
    println!(
        "summary: {} churn epochs over {} live edges: repair_frac mean={:.5} max={:.5} (batch/live={:.5}); epoch latency p50={p50:.1}ms p99={p99:.1}ms (mutate p50={mutate_p50:.2}ms = run {run_p50:.2}ms + spawn overhead {spawn_p50:.3}ms [{} dispatch]; route p50={route_p50:.2}ms; P={}); |M|={}; verified {}/{} epochs",
        summary.epochs,
        summary.final_live_edges,
        summary.repair_frac_mean,
        summary.repair_frac_max,
        cfg.batch as f64 / summary.final_live_edges.max(1) as f64,
        cfg.shard_exec().name(),
        cfg.engine_shards,
        summary.final_matched_vertices / 2,
        summary.verified_epochs,
        summary.epochs + summary.warmup_epochs,
    );
    if let Some(path) = &cfg.save {
        println!(
            "saved engine state ({} live edges, |M|={}) to {path}",
            summary.final_live_edges,
            summary.final_matched_vertices / 2
        );
    }
    if let Some(path) = args.get("record") {
        let rec = registry::churn_record(&cfg, &summary);
        rec.write_file(Path::new(path))?;
        println!(
            "recorded bench {} (config {}) -> {path}; publish or gate it with `skipper-cli report`",
            rec.bench,
            rec.config_hash()
        );
    }
    if let Some(path) = args.get("metrics-file") {
        std::fs::write(path, &summary.metrics_text).map_err(|e| format!("{path}: {e}"))?;
        println!("metrics: end-of-run Prometheus exposition -> {path}");
    }
    if let Some(path) = trace_out {
        trace::set_enabled(false);
        let events = trace::collect();
        let doc = trace::chrome_trace_json(&events);
        std::fs::write(path, doc.render_pretty()).map_err(|e| format!("{path}: {e}"))?;
        println!("trace: {} spans -> {path} (load in chrome://tracing)", events.len());
    }
    Ok(())
}

/// The perf-trajectory registry: render, publish, or gate `BENCH_*.json`.
fn cmd_report(args: &Args) -> Result<(), String> {
    let dir = Path::new(args.get_or("dir", "BENCH"));
    if args.get("publish").is_some() && args.get("gate").is_some() {
        return Err("--publish and --gate are mutually exclusive".into());
    }
    if let Some(cand) = args.get("publish") {
        let rec = BenchRecord::read_file(Path::new(cand))?;
        let (bench, hash) = (rec.bench.clone(), rec.config_hash());
        let mut reg = Registry::load_or_new(dir, &bench)?;
        reg.publish(rec)?;
        let path = reg.save(dir)?;
        println!(
            "published {bench} run (config {hash}) -> {} ({} committed runs)",
            path.display(),
            reg.runs.len()
        );
        return Ok(());
    }
    if let Some(cand) = args.get("gate") {
        let threshold: f64 = args.get_parse("threshold", registry::DEFAULT_THRESHOLD)?;
        let rec = BenchRecord::read_file(Path::new(cand))?;
        let reg = Registry::load_or_new(dir, &rec.bench)?;
        let out = registry::gate(&reg, &rec, threshold);
        println!("gating {} (config {}) against {}", rec.bench, rec.config_hash(), dir.display());
        for line in &out.lines {
            println!("  {line}");
        }
        return if out.pass {
            println!("gate: PASS{}", if out.seeded { " (seeding run)" } else { "" });
            Ok(())
        } else {
            Err(format!(
                "gate: FAIL — {} regressed beyond ±{:.0}% of the committed baseline",
                rec.bench,
                threshold * 100.0
            ))
        };
    }
    let regs = Registry::load_dir(dir)?;
    print!("{}", registry::report_markdown(&regs));
    Ok(())
}

/// Validate observability artifacts offline — the CI smoke gate behind the
/// `serve`/`churn` metrics and trace outputs.
fn cmd_lint(args: &Args) -> Result<(), String> {
    let metrics_path = args.get("metrics");
    let trace_path = args.get("trace");
    if metrics_path.is_none() && trace_path.is_none() {
        return Err("lint needs --metrics FILE and/or --trace FILE".into());
    }
    if args.get("require").is_some() && trace_path.is_none() {
        return Err("--require asserts span names, so it needs --trace FILE".into());
    }
    if args.get("require-exemplars").is_some() && metrics_path.is_none() {
        return Err("--require-exemplars asserts exemplar labels, so it needs --metrics FILE".into());
    }
    let mut metrics_text = None;
    if let Some(path) = metrics_path {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        metrics::validate_prometheus(&text).map_err(|e| format!("{path}: {e}"))?;
        println!(
            "lint: {path}: valid Prometheus exposition ({} lines)",
            text.lines().count()
        );
        metrics_text = Some(text);
    }
    let mut trace_text = None;
    if let Some(path) = trace_path {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let names = trace::validate_chrome_trace(&text).map_err(|e| format!("{path}: {e}"))?;
        println!(
            "lint: {path}: well-formed Chrome trace ({} distinct span names)",
            names.len()
        );
        if let Some(req) = args.get("require") {
            for want in req.split(',').map(str::trim).filter(|s| !s.is_empty()) {
                if !names.iter().any(|n| n == want) {
                    return Err(format!(
                        "{path}: required span {want:?} not present (have: {})",
                        names.join(", ")
                    ));
                }
            }
            println!("lint: {path}: all required spans present ({req})");
        }
        trace_text = Some(text);
    }
    if let Some(req) = args.get("require-exemplars") {
        // presence of --metrics was checked up front
        let mpath = metrics_path.unwrap();
        let text = metrics_text.as_deref().unwrap();
        // when a trace rides along, exemplar span ids must resolve into it
        let trace_ids = match (&trace_text, trace_path) {
            (Some(t), Some(tpath)) => {
                Some(trace::chrome_trace_span_ids(t).map_err(|e| format!("{tpath}: {e}"))?)
            }
            _ => None,
        };
        for family in req.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let ids = metrics::exemplar_span_ids(text, family);
            if ids.is_empty() {
                return Err(format!(
                    "{mpath}: histogram family {family:?} carries no bucket exemplars \
                     (was the run traced? exemplars attach only inside live spans)"
                ));
            }
            if let (Some(trace_ids), Some(tpath)) = (&trace_ids, trace_path) {
                for id in &ids {
                    if !trace_ids.iter().any(|t| t == id) {
                        return Err(format!(
                            "{mpath}: exemplar span_id {id:?} on family {family:?} does not \
                             resolve to any span in {tpath} (dangling span id)"
                        ));
                    }
                }
            }
            println!(
                "lint: {mpath}: family {family}: {} exemplar span id{}{}",
                ids.len(),
                if ids.len() == 1 { "" } else { "s" },
                if trace_ids.is_some() {
                    ", all resolve in the trace"
                } else {
                    ""
                }
            );
        }
    }
    Ok(())
}

/// Render the committed perf registries (and an optional live metrics
/// snapshot) as one self-contained static HTML dashboard.
fn cmd_dash(args: &Args) -> Result<(), String> {
    let dir = Path::new(args.get_or("dir", "BENCH"));
    let out = args.get_or("out", "dash.html");
    if args.get("metrics").is_some() && args.get("metrics-addr").is_some() {
        return Err("--metrics and --metrics-addr are mutually exclusive".into());
    }
    let live = if let Some(path) = args.get("metrics") {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Some(LiveSource { origin: path.to_string(), text })
    } else if let Some(addr) = args.get("metrics-addr") {
        Some(LiveSource {
            origin: format!("http://{addr}/metrics"),
            text: scrape_metrics(addr)?,
        })
    } else {
        None
    };
    let regs = Registry::load_dir(dir)?;
    let html = render_dash(&regs, live.as_ref());
    std::fs::write(out, &html).map_err(|e| format!("{out}: {e}"))?;
    println!(
        "dash: {} bench registr{} ({} committed runs){} -> {out}",
        regs.len(),
        if regs.len() == 1 { "y" } else { "ies" },
        regs.iter().map(|r| r.runs.len()).sum::<usize>(),
        if live.is_some() { " + live snapshot" } else { "" },
    );
    Ok(())
}

/// One-shot `GET /metrics` scrape of a `serve --metrics-addr` endpoint.
fn scrape_metrics(addr: &str) -> Result<String, String> {
    use std::io::{Read, Write};
    let mut stream = std::net::TcpStream::connect(addr).map_err(|e| format!("{addr}: {e}"))?;
    stream
        .set_read_timeout(Some(std::time::Duration::from_secs(10)))
        .map_err(|e| format!("{addr}: {e}"))?;
    let req = format!("GET /metrics HTTP/1.0\r\nHost: {addr}\r\nConnection: close\r\n\r\n");
    stream
        .write_all(req.as_bytes())
        .map_err(|e| format!("{addr}: {e}"))?;
    let mut raw = String::new();
    stream
        .read_to_string(&mut raw)
        .map_err(|e| format!("{addr}: {e}"))?;
    let (head, body) = raw
        .split_once("\r\n\r\n")
        .ok_or_else(|| format!("{addr}: malformed HTTP response"))?;
    let status = head.lines().next().unwrap_or("");
    if !status.contains(" 200 ") {
        return Err(format!("{addr}: scrape failed: {status}"));
    }
    Ok(body.to_string())
}

fn cmd_info() -> Result<(), String> {
    println!("Suite datasets (scaled analogues of the paper's Table I):");
    for spec in &SUITE {
        println!(
            "  {:<12} ({:<6}) analogue of {}",
            spec.name, spec.kind, spec.paper_name
        );
    }
    println!("\nScales: tiny (trace/cachesim), small (default), medium, large");
    println!("Artifacts dir: {}", skipper::runtime::artifacts_dir());
    Ok(())
}
