//! Property tests for the observability subsystem.
//!
//! * **Histogram percentiles vs exact**: for random sample sets spanning
//!   the full `u64` magnitude range, the log-scale histogram's nearest-rank
//!   percentile must bracket the exact (sorted-samples) nearest-rank value
//!   from above, within one bucket's relative width: `exact ≤ est` and
//!   `est − exact ≤ exact/8` (the bucket invariant `hi − lo ≤ lo/8`).
//!   Count and (wrapping) sum must be exact, not approximate.
//! * **Exposition validity**: a registry populated with random counters,
//!   gauges, and histograms always renders text that its own
//!   [`validate_prometheus`] accepts — the exporter and the CI linter can
//!   never drift apart.
//! * **Trace validity**: a Chrome trace document built from arbitrary span
//!   events always passes [`validate_chrome_trace`], and the validator
//!   reports exactly the span names that went in.
//!
//! [`validate_prometheus`]: skipper::obs::metrics::validate_prometheus
//! [`validate_chrome_trace`]: skipper::obs::trace::validate_chrome_trace

use skipper::obs::metrics::{validate_prometheus, Histogram, Registry};
use skipper::obs::trace::{chrome_trace_json, validate_chrome_trace, SpanEvent};
use skipper::util::qcheck::{check, Config};
use skipper::util::rng::Xoshiro256pp;

/// Exact nearest-rank percentile of `sorted` (the definition
/// `Histogram::percentile` approximates): the k-th smallest sample with
/// `k = ceil(p/100 · n)` clamped to `1..=n`.
fn exact_nearest_rank(sorted: &[u64], p: f64) -> u64 {
    let n = sorted.len();
    let rank = ((p / 100.0) * n as f64).ceil().clamp(1.0, n as f64) as usize;
    sorted[rank - 1]
}

/// Samples spanning the whole magnitude range: a uniform `u64` shifted
/// right by a uniform amount lands in every octave with equal probability,
/// which is exactly the regime the log-scale buckets are built for.
fn arb_samples(rng: &mut Xoshiro256pp) -> Vec<u64> {
    let len = 1 + rng.next_usize(400);
    (0..len).map(|_| rng.next_u64() >> rng.next_usize(64)).collect()
}

#[test]
fn histogram_percentiles_bracket_exact_within_one_bucket() {
    check(
        &Config { cases: 200, seed: 0x0B5E, max_shrink_steps: 0 },
        arb_samples,
        |samples| {
            let h = Histogram::new();
            let mut wrap_sum = 0u64;
            for &v in samples {
                h.record(v);
                wrap_sum = wrap_sum.wrapping_add(v);
            }
            if h.count() != samples.len() as u64 {
                return Err(format!("count {} != {}", h.count(), samples.len()));
            }
            if h.sum() != wrap_sum {
                return Err(format!("sum {} != {wrap_sum}", h.sum()));
            }
            let mut sorted = samples.clone();
            sorted.sort_unstable();
            for p in [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 99.0, 99.9, 100.0] {
                let exact = exact_nearest_rank(&sorted, p);
                let est = h.percentile(p);
                if est < exact {
                    return Err(format!("p{p}: estimate {est} under-reports exact {exact}"));
                }
                if est - exact > exact / 8 {
                    return Err(format!(
                        "p{p}: estimate {est} beyond one bucket above exact {exact} \
                         (err {} > {})",
                        est - exact,
                        exact / 8
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn empty_histogram_reports_zero_everywhere() {
    let h = Histogram::new();
    assert_eq!(h.count(), 0);
    assert_eq!(h.sum(), 0);
    assert_eq!(h.percentile(50.0), 0);
    assert_eq!(h.percentile(100.0), 0);
    assert!(h.cumulative_buckets().is_empty());
}

/// A random mix of instruments on one registry; returns the seed so each
/// case draws different names/values.
fn arb_registry_seed(rng: &mut Xoshiro256pp) -> u64 {
    rng.next_u64()
}

#[test]
fn random_registries_always_render_valid_prometheus() {
    check(
        &Config { cases: 60, seed: 0x9E75, max_shrink_steps: 0 },
        arb_registry_seed,
        |&seed| {
            let mut rng = Xoshiro256pp::new(seed);
            let reg = Registry::new();
            for i in 0..1 + rng.next_usize(6) {
                let c = reg.counter(&format!("prop_ops_{i}_total"), "random counter");
                c.add(rng.next_u64() >> 40);
            }
            for i in 0..rng.next_usize(4) {
                let g = reg.gauge(&format!("prop_depth_{i}"), "random gauge");
                g.set(rng.next_u64() >> 50);
            }
            for i in 0..rng.next_usize(3) {
                let f = reg.fgauge(&format!("prop_frac_{i}"), "random fgauge");
                f.set(rng.next_f64());
            }
            for i in 0..rng.next_usize(3) {
                let shard = rng.next_usize(4).to_string();
                let h = reg.histogram_secs_with(
                    &format!("prop_latency_{i}_seconds"),
                    "random histogram",
                    vec![("shard".to_string(), shard)],
                );
                for _ in 0..rng.next_usize(50) {
                    h.record(rng.next_u64() >> rng.next_usize(64));
                }
            }
            let text = reg.render_prometheus();
            if !text.ends_with("# EOF\n") {
                return Err("exposition does not end with # EOF".into());
            }
            validate_prometheus(&text).map_err(|e| format!("{e}\n---\n{text}"))
        },
    );
}

const SPAN_NAMES: [&str; 5] = ["mutate", "repair", "route", "wal_append", "pool_run"];
const SPAN_CATS: [&str; 3] = ["engine", "wal", "pool"];

fn arb_events(rng: &mut Xoshiro256pp) -> Vec<SpanEvent> {
    let len = rng.next_usize(60);
    (0..len)
        .map(|_| SpanEvent {
            name: SPAN_NAMES[rng.next_usize(SPAN_NAMES.len())],
            cat: SPAN_CATS[rng.next_usize(SPAN_CATS.len())],
            ts_us: rng.next_u64() >> 24,
            dur_us: rng.next_u64() >> 40,
            tid: rng.next_u64() >> 56,
            epoch: rng.next_u64() >> 48,
            arg: rng.next_u64() >> 32,
        })
        .collect()
}

#[test]
fn chrome_trace_documents_validate_and_preserve_span_names() {
    check(
        &Config { cases: 100, seed: 0x7CA3, max_shrink_steps: 0 },
        arb_events,
        |events| {
            let text = chrome_trace_json(events).render_compact();
            let names = validate_chrome_trace(&text).map_err(|e| format!("{e}\n---\n{text}"))?;
            for ev in events {
                if !names.iter().any(|n| n == ev.name) {
                    return Err(format!("span name {:?} lost in the document", ev.name));
                }
            }
            for n in &names {
                if !events.iter().any(|ev| ev.name == n.as_str()) {
                    return Err(format!("validator invented span name {n:?}"));
                }
            }
            Ok(())
        },
    );
}
