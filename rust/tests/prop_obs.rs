//! Property tests for the observability subsystem.
//!
//! * **Histogram percentiles vs exact**: for random sample sets spanning
//!   the full `u64` magnitude range, the log-scale histogram's nearest-rank
//!   percentile must bracket the exact (sorted-samples) nearest-rank value
//!   from above, within one bucket's relative width: `exact ≤ est` and
//!   `est − exact ≤ exact/8` (the bucket invariant `hi − lo ≤ lo/8`).
//!   Count and (wrapping) sum must be exact, not approximate.
//! * **Exposition validity**: a registry populated with random counters,
//!   gauges, and histograms always renders text that its own
//!   [`validate_prometheus`] accepts — the exporter and the CI linter can
//!   never drift apart.
//! * **Trace validity**: a Chrome trace document built from arbitrary span
//!   events always passes [`validate_chrome_trace`], and the validator
//!   reports exactly the span names that went in.
//! * **Exemplar attachment**: for random sample streams recorded inside a
//!   live span, every non-empty bucket retains exactly its most recent
//!   sample as the exemplar (stamped with the span's epoch and id), and
//!   samples recorded outside any span never attach one.
//! * **Exemplar exposition round-trip**: expositions whose bucket lines
//!   carry `# {span_id="…"}` exemplar annotations still pass
//!   [`validate_prometheus`], and the annotated ids parse back out via
//!   [`exemplar_span_ids`].
//! * **Dash determinism**: the `skipper-cli dash` HTML is a pure function
//!   of its inputs — rendering random registries twice is byte-identical,
//!   and the document never contains a `<script` tag.
//!
//! [`validate_prometheus`]: skipper::obs::metrics::validate_prometheus
//! [`validate_chrome_trace`]: skipper::obs::trace::validate_chrome_trace
//! [`exemplar_span_ids`]: skipper::obs::metrics::exemplar_span_ids

use skipper::coordinator::dash::{render_dash, LiveSource};
use skipper::coordinator::registry::{BenchRecord, Registry as BenchRegistry};
use skipper::obs::metrics::{
    bucket_of, exemplar_span_ids, validate_prometheus, Histogram, Registry,
};
use skipper::obs::trace::{self, chrome_trace_json, validate_chrome_trace, SpanEvent};
use skipper::util::qcheck::{check, Config};
use skipper::util::rng::Xoshiro256pp;
use std::collections::BTreeMap;

/// The trace gate is process-global: the two exemplar tests below both
/// toggle it, so they serialize on this lock to keep `cargo test`'s
/// parallel runner from disabling tracing under each other.
static TRACE_GATE: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Exact nearest-rank percentile of `sorted` (the definition
/// `Histogram::percentile` approximates): the k-th smallest sample with
/// `k = ceil(p/100 · n)` clamped to `1..=n`.
fn exact_nearest_rank(sorted: &[u64], p: f64) -> u64 {
    let n = sorted.len();
    let rank = ((p / 100.0) * n as f64).ceil().clamp(1.0, n as f64) as usize;
    sorted[rank - 1]
}

/// Samples spanning the whole magnitude range: a uniform `u64` shifted
/// right by a uniform amount lands in every octave with equal probability,
/// which is exactly the regime the log-scale buckets are built for.
fn arb_samples(rng: &mut Xoshiro256pp) -> Vec<u64> {
    let len = 1 + rng.next_usize(400);
    (0..len).map(|_| rng.next_u64() >> rng.next_usize(64)).collect()
}

#[test]
fn histogram_percentiles_bracket_exact_within_one_bucket() {
    check(
        &Config { cases: 200, seed: 0x0B5E, max_shrink_steps: 0 },
        arb_samples,
        |samples| {
            let h = Histogram::new();
            let mut wrap_sum = 0u64;
            for &v in samples {
                h.record(v);
                wrap_sum = wrap_sum.wrapping_add(v);
            }
            if h.count() != samples.len() as u64 {
                return Err(format!("count {} != {}", h.count(), samples.len()));
            }
            if h.sum() != wrap_sum {
                return Err(format!("sum {} != {wrap_sum}", h.sum()));
            }
            let mut sorted = samples.clone();
            sorted.sort_unstable();
            for p in [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 99.0, 99.9, 100.0] {
                let exact = exact_nearest_rank(&sorted, p);
                let est = h.percentile(p);
                if est < exact {
                    return Err(format!("p{p}: estimate {est} under-reports exact {exact}"));
                }
                if est - exact > exact / 8 {
                    return Err(format!(
                        "p{p}: estimate {est} beyond one bucket above exact {exact} \
                         (err {} > {})",
                        est - exact,
                        exact / 8
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn empty_histogram_reports_zero_everywhere() {
    let h = Histogram::new();
    assert_eq!(h.count(), 0);
    assert_eq!(h.sum(), 0);
    assert_eq!(h.percentile(50.0), 0);
    assert_eq!(h.percentile(100.0), 0);
    assert!(h.cumulative_buckets().is_empty());
}

/// A random mix of instruments on one registry; returns the seed so each
/// case draws different names/values.
fn arb_registry_seed(rng: &mut Xoshiro256pp) -> u64 {
    rng.next_u64()
}

#[test]
fn random_registries_always_render_valid_prometheus() {
    check(
        &Config { cases: 60, seed: 0x9E75, max_shrink_steps: 0 },
        arb_registry_seed,
        |&seed| {
            let mut rng = Xoshiro256pp::new(seed);
            let reg = Registry::new();
            for i in 0..1 + rng.next_usize(6) {
                let c = reg.counter(&format!("prop_ops_{i}_total"), "random counter");
                c.add(rng.next_u64() >> 40);
            }
            for i in 0..rng.next_usize(4) {
                let g = reg.gauge(&format!("prop_depth_{i}"), "random gauge");
                g.set(rng.next_u64() >> 50);
            }
            for i in 0..rng.next_usize(3) {
                let f = reg.fgauge(&format!("prop_frac_{i}"), "random fgauge");
                f.set(rng.next_f64());
            }
            for i in 0..rng.next_usize(3) {
                let shard = rng.next_usize(4).to_string();
                let h = reg.histogram_secs_with(
                    &format!("prop_latency_{i}_seconds"),
                    "random histogram",
                    vec![("shard".to_string(), shard)],
                );
                for _ in 0..rng.next_usize(50) {
                    h.record(rng.next_u64() >> rng.next_usize(64));
                }
            }
            let text = reg.render_prometheus();
            if !text.ends_with("# EOF\n") {
                return Err("exposition does not end with # EOF".into());
            }
            validate_prometheus(&text).map_err(|e| format!("{e}\n---\n{text}"))
        },
    );
}

const SPAN_NAMES: [&str; 5] = ["mutate", "repair", "route", "wal_append", "pool_run"];
const SPAN_CATS: [&str; 3] = ["engine", "wal", "pool"];

fn arb_events(rng: &mut Xoshiro256pp) -> Vec<SpanEvent> {
    let len = rng.next_usize(60);
    (0..len)
        .map(|_| SpanEvent {
            name: SPAN_NAMES[rng.next_usize(SPAN_NAMES.len())],
            cat: SPAN_CATS[rng.next_usize(SPAN_CATS.len())],
            ts_us: rng.next_u64() >> 24,
            dur_us: rng.next_u64() >> 40,
            tid: rng.next_u64() >> 56,
            epoch: rng.next_u64() >> 48,
            arg: rng.next_u64() >> 32,
            span_id: 1 + (rng.next_u64() >> 32),
        })
        .collect()
}

#[test]
fn exemplars_attach_buckets_most_recent_in_span_sample() {
    let _gate = TRACE_GATE.lock().unwrap_or_else(|e| e.into_inner());
    check(
        &Config { cases: 60, seed: 0xE4A1, max_shrink_steps: 0 },
        arb_samples,
        |samples| {
            trace::set_enabled(true);
            let h = Histogram::new();
            // the model: last sample recorded into each bucket wins
            let mut expect: BTreeMap<usize, u64> = BTreeMap::new();
            let epoch = 7u64;
            {
                let _sp = trace::span_epoch("prop_exemplar", "test", epoch, 0);
                for &v in samples {
                    h.record(v);
                    expect.insert(bucket_of(v), v);
                }
            }
            trace::set_enabled(false);
            let got = h.exemplars();
            if got.len() != expect.len() {
                return Err(format!(
                    "{} exemplar slots for {} non-empty buckets",
                    got.len(),
                    expect.len()
                ));
            }
            for (idx, ex) in &got {
                match expect.get(idx) {
                    Some(&v) if v == ex.value => {}
                    Some(&v) => {
                        return Err(format!(
                            "bucket {idx}: exemplar {} is not the most recent sample {v}",
                            ex.value
                        ))
                    }
                    None => return Err(format!("bucket {idx}: exemplar on an empty bucket")),
                }
                if ex.epoch != epoch {
                    return Err(format!("bucket {idx}: epoch {} != {epoch}", ex.epoch));
                }
                if ex.span_id == 0 {
                    return Err(format!("bucket {idx}: zero span id"));
                }
            }
            // samples recorded outside any span never attach an exemplar,
            // even with the trace gate still conceptually relevant
            for &v in samples.iter().take(8) {
                h.record(v);
            }
            if h.exemplars() != got {
                return Err("out-of-span records changed the exemplar set".into());
            }
            Ok(())
        },
    );
}

#[test]
fn exemplar_expositions_round_trip_the_validator() {
    let _gate = TRACE_GATE.lock().unwrap_or_else(|e| e.into_inner());
    check(
        &Config { cases: 40, seed: 0xE4A2, max_shrink_steps: 0 },
        arb_registry_seed,
        |&seed| {
            trace::set_enabled(true);
            let mut rng = Xoshiro256pp::new(seed);
            let reg = Registry::new();
            let families = 1 + rng.next_usize(3);
            for i in 0..families {
                let h = reg.histogram_secs(&format!("prop_ex_{i}_seconds"), "random histogram");
                let _sp = trace::span_epoch("prop_ex", "test", i as u64 + 1, 0);
                for _ in 0..1 + rng.next_usize(40) {
                    h.record(1 + (rng.next_u64() >> rng.next_usize(64)));
                }
            }
            trace::set_enabled(false);
            let text = reg.render_prometheus();
            if !text.contains(" # {span_id=\"") {
                return Err(format!("no exemplar annotations rendered:\n{text}"));
            }
            validate_prometheus(&text).map_err(|e| format!("{e}\n---\n{text}"))?;
            for i in 0..families {
                let ids = exemplar_span_ids(&text, &format!("prop_ex_{i}_seconds"));
                if ids.is_empty() {
                    return Err(format!("family prop_ex_{i}_seconds lost its exemplars"));
                }
                for id in &ids {
                    if id.len() != 16 || !id.bytes().all(|b| b.is_ascii_hexdigit()) {
                        return Err(format!("span id {id:?} is not 16 hex digits"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn dash_html_renders_deterministically_for_random_registries() {
    check(
        &Config { cases: 40, seed: 0xDA54, max_shrink_steps: 0 },
        arb_registry_seed,
        |&seed| {
            let mut rng = Xoshiro256pp::new(seed);
            let mut regs = Vec::new();
            for b in 0..1 + rng.next_usize(3) {
                let bench = format!("prop_dash_{b}");
                let mut reg = BenchRegistry::new(&bench);
                for r in 0..rng.next_usize(5) {
                    let mut config = BTreeMap::new();
                    config.insert("workload".to_string(), format!("w{}", rng.next_usize(2)));
                    let mut met = BTreeMap::new();
                    for m in 0..1 + rng.next_usize(4) {
                        met.insert(format!("metric_{m}_per_s"), rng.next_f64() * 1e6);
                    }
                    met.insert("exact_items".to_string(), rng.next_usize(100) as f64);
                    let mut rec = BenchRecord::new(bench.clone(), config, met);
                    // pin the timestamp: rendered HTML must not depend on now
                    rec.recorded_unix_s = 1_700_000_000 + r as u64;
                    reg.publish(rec).map_err(|e| format!("publish: {e}"))?;
                }
                regs.push(reg);
            }
            let live = LiveSource { origin: "prop".into(), text: "# EOF\n".into() };
            let a = render_dash(&regs, Some(&live));
            let b = render_dash(&regs, Some(&live));
            if a != b {
                return Err("dash render is not byte-deterministic".into());
            }
            if a.contains("<script") {
                return Err("dash document must carry no JavaScript".into());
            }
            Ok(())
        },
    );
}

#[test]
fn chrome_trace_documents_validate_and_preserve_span_names() {
    check(
        &Config { cases: 100, seed: 0x7CA3, max_shrink_steps: 0 },
        arb_events,
        |events| {
            let text = chrome_trace_json(events).render_compact();
            let names = validate_chrome_trace(&text).map_err(|e| format!("{e}\n---\n{text}"))?;
            for ev in events {
                if !names.iter().any(|n| n == ev.name) {
                    return Err(format!("span name {:?} lost in the document", ev.name));
                }
            }
            for n in &names {
                if !events.iter().any(|ev| ev.name == n.as_str()) {
                    return Err(format!("validator invented span name {n:?}"));
                }
            }
            Ok(())
        },
    );
}
