//! Integration: the AOT path end-to-end — HLO text artifacts produced by
//! `python/compile/aot.py`, loaded and compiled by the PJRT CPU client,
//! executed from rust, validated against the rust matchers.
//!
//! Skips (with a message) when `artifacts/` is absent; `make test` always
//! builds artifacts first.

use skipper::graph::builder::{build, BuildOptions};
use skipper::graph::gen::{erdos_renyi, rmat, simple, GenConfig};
use skipper::graph::EdgeList;
use skipper::matching::ems::idmm::Idmm;
use skipper::matching::{verify, MaximalMatcher};
use skipper::runtime::{Manifest, XlaEmsMatcher};

fn matcher_or_skip() -> Option<XlaEmsMatcher> {
    match XlaEmsMatcher::from_default_artifacts() {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e:#}");
            None
        }
    }
}

#[test]
fn manifest_lists_shipped_variants() {
    let dir = skipper::runtime::artifacts_dir();
    let Ok(m) = Manifest::load(&dir) else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    assert!(m.artifacts.len() >= 3);
    for a in &m.artifacts {
        assert!(std::path::Path::new(&m.full_path(a)).exists(), "{}", a.path);
    }
}

#[test]
fn xla_ems_matches_small_graphs() {
    let Some(matcher) = matcher_or_skip() else { return };
    for g in [
        simple::path(40),
        simple::cycle(41),
        simple::star(64),
        simple::complete(16),
        erdos_renyi::generate(200, 400, 3),
    ] {
        let (m, rounds) = matcher.match_graph(&g).expect("xla run");
        verify::check(&g, &m).expect("xla matching invalid");
        assert!(rounds >= 1);
    }
}

#[test]
fn xla_ems_agrees_with_rust_idmm() {
    // Same algorithm, same priorities (edge ids in canonical order) —
    // the tensorized EMS must produce the identical deterministic matching.
    let Some(matcher) = matcher_or_skip() else { return };
    let g = rmat::generate(&GenConfig { scale: 7, avg_degree: 3, seed: 5 });
    let (xla_m, _) = matcher.match_graph(&g).expect("xla run");
    let rust_m = Idmm::default().run(&g);
    assert_eq!(xla_m.to_sorted_vec(), rust_m.to_sorted_vec());
}

#[test]
fn xla_ems_picks_fitting_variants() {
    let Some(matcher) = matcher_or_skip() else { return };
    let exe = matcher.executable_for(100, 500).expect("variant");
    assert_eq!(exe.num_vertices, 256);
    let exe = matcher.executable_for(1000, 4000).expect("variant");
    assert_eq!(exe.num_vertices, 1024);
    assert!(matcher.executable_for(1 << 20, 1).is_err());
}

#[test]
fn xla_ems_handles_sparse_padding() {
    // one real edge in a sea of padding
    let Some(matcher) = matcher_or_skip() else { return };
    let mut el = EdgeList::new(10);
    el.push(3, 7);
    let g = build(&el, BuildOptions::default());
    let (m, _) = matcher.match_graph(&g).expect("xla run");
    assert_eq!(m.to_sorted_vec(), vec![(3, 7)]);
}

#[test]
fn xla_ems_empty_graph() {
    let Some(matcher) = matcher_or_skip() else { return };
    let g = skipper::graph::CsrGraph::from_parts(vec![0, 0, 0], vec![]).unwrap();
    let (m, _) = matcher.match_graph(&g).expect("xla run");
    assert_eq!(m.len(), 0);
}

#[test]
fn padded_execution_rejects_bad_lengths() {
    let Some(matcher) = matcher_or_skip() else { return };
    let exe = matcher.executable_for(100, 500).expect("variant");
    let bad = vec![0i32; 7];
    assert!(exe.run_padded(&bad, &bad, &bad).is_err());
}
