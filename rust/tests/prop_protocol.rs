//! Hostile-input property tests for the line protocol.
//!
//! The service promises byte-tolerant framing: whatever a client writes —
//! truncated UTF-8 sequences, raw control bytes, unknown verbs, wrong
//! arities, numeric overflow, oversized batches of out-of-range vertices —
//! every non-blank, non-comment line gets **exactly one** structured JSON
//! reply (`"ok":false` with an error message for the garbage), the
//! connection never drops, framing never desyncs, and the server never
//! panics. After an arbitrary junk prefix, a valid INSERT/EPOCH/QUERY tail
//! must still work and see exactly its own writes.
//!
//! The reply-count oracle reuses the server's own framing rule: decode the
//! line lossily, trim, and expect a reply iff the result is non-blank and
//! not a `#` comment.

use skipper::service::protocol::Command;
use skipper::service::{serve_lines, ServiceConfig};
use skipper::util::rng::Xoshiro256pp;

/// A random ASCII word of `len` uppercase letters.
fn word(rng: &mut Xoshiro256pp, len: usize) -> Vec<u8> {
    (0..len).map(|_| b'A' + rng.next_usize(26) as u8).collect()
}

/// One adversarial input line (no trailing newline).
fn junk_line(rng: &mut Xoshiro256pp) -> Vec<u8> {
    match rng.next_usize(10) {
        // raw bytes, newline excluded — mostly invalid UTF-8
        0 => {
            let len = rng.next_usize(33);
            (0..len)
                .map(|_| {
                    let b = 1 + rng.next_usize(255) as u8;
                    if b == b'\n' {
                        0xFF
                    } else {
                        b
                    }
                })
                .collect()
        }
        // a real verb cut off mid-multibyte-sequence
        1 => b"QUERY 1 \xe2\x82".to_vec(),
        // unknown verb with plausible arguments
        2 => {
            let len = 2 + rng.next_usize(10);
            let mut l = word(rng, len);
            l.extend_from_slice(b" 1 2");
            l
        }
        // blank-ish lines: empty, spaces, a tab
        3 => [b"" as &[u8], b"   ", b"\t"][rng.next_usize(3)].to_vec(),
        // comments
        4 => b"# a comment the server must skip silently".to_vec(),
        // known verbs, wrong arity
        5 => [
            b"INSERT" as &[u8],
            b"INSERT 5",
            b"DELETE 1 2 3",
            b"QUERY",
            b"QUERY 1 2",
            b"EPOCH now",
            b"STATS verbose",
            b"PROMOTE please",
        ][rng.next_usize(8)]
            .to_vec(),
        // numeric garbage: overflow, sign, radix prefixes
        6 => [
            b"QUERY 18446744073709551616999" as &[u8],
            b"INSERT -1 -2",
            b"QUERY 0x10",
            b"INSERT 1e9 2",
        ][rng.next_usize(4)]
            .to_vec(),
        // an oversized batch of out-of-range vertices: parses fine, must
        // come back as one bounds error, not 2·k queued updates
        7 => {
            let mut l = b"INSERT".to_vec();
            for _ in 0..100 + rng.next_usize(400) {
                l.extend_from_slice(b" 1000000 1000001");
            }
            l
        }
        // one huge unbroken token
        8 => {
            let len = 2000 + rng.next_usize(3000);
            word(rng, len)
        }
        // valid but harmless commands mixed into the junk
        9 => [b"QUERY 3" as &[u8], b"EPOCH", b"STATS"][rng.next_usize(3)].to_vec(),
        _ => unreachable!(),
    }
}

/// The framing oracle: does this input line owe the client a reply?
fn expects_reply(line: &[u8]) -> bool {
    let text = String::from_utf8_lossy(line);
    let t = text.trim();
    !t.is_empty() && !t.starts_with('#')
}

#[test]
fn every_junk_line_gets_one_structured_reply_and_framing_never_desyncs() {
    let mut rng = Xoshiro256pp::new(0xF0_22);
    for case in 0..20 {
        let num = 30 + rng.next_usize(40);
        let mut script: Vec<u8> = Vec::new();
        let mut expected = 0usize;
        for _ in 0..num {
            let line = junk_line(&mut rng);
            if expects_reply(&line) {
                expected += 1;
            }
            script.extend_from_slice(&line);
            script.push(b'\n');
        }
        // a valid tail: the session must still be fully functional
        script.extend_from_slice(b"INSERT 0 1\nEPOCH\nQUERY 0\nQUIT\n");
        expected += 4;

        let cfg = ServiceConfig {
            num_vertices: 64,
            threads: 1,
            engine_shards: 2,
            ..Default::default()
        };
        let mut out = Vec::new();
        let summary = serve_lines(&cfg, script.as_slice(), &mut out)
            .unwrap_or_else(|e| panic!("case {case}: server errored on junk: {e}"));
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(
            lines.len(),
            expected,
            "case {case}: exactly one reply per command line\n{text}"
        );
        for l in &lines {
            assert!(l.contains(r#""ok":"#), "case {case}: unstructured reply: {l}");
        }
        // in-order framing survived: the tail's replies are the last four
        assert!(lines[expected - 4].contains(r#""op":"queued""#), "case {case}");
        assert!(lines[expected - 3].contains(r#""op":"epoch""#), "case {case}");
        let q = lines[expected - 2];
        assert!(
            q.contains(r#""op":"query""#) && q.contains(r#""partner":1"#),
            "case {case}: junk polluted the engine: {q}"
        );
        assert!(lines[expected - 1].contains(r#""op":"bye""#), "case {case}");
        assert!(summary.maximal, "case {case}");
    }
}

#[test]
fn command_parse_never_panics_on_arbitrary_bytes() {
    let mut rng = Xoshiro256pp::new(0xBEEF);
    for _ in 0..5000 {
        let len = rng.next_usize(65);
        let bytes: Vec<u8> = (0..len).map(|_| rng.next_usize(256) as u8).collect();
        // the server decodes lossily before parsing; do the same
        let line = String::from_utf8_lossy(&bytes);
        let _ = Command::parse(&line);
    }
}

#[test]
fn oversized_batch_of_out_of_range_vertices_is_one_error() {
    let mut script = b"INSERT".to_vec();
    for _ in 0..5000 {
        script.extend_from_slice(b" 70000 70001");
    }
    script.extend_from_slice(b"\nQUERY 0\nQUIT\n");
    let cfg = ServiceConfig { num_vertices: 64, threads: 1, engine_shards: 1, ..Default::default() };
    let mut out = Vec::new();
    serve_lines(&cfg, script.as_slice(), &mut out).unwrap();
    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 3, "{text}");
    assert!(lines[0].contains(r#""ok":false"#) && lines[0].contains("out of range"), "{}", lines[0]);
    assert!(lines[1].contains(r#""matched":false"#), "{}", lines[1]);
    assert!(lines[2].contains(r#""op":"bye""#), "{}", lines[2]);
}
