//! Integration: every matching algorithm × the whole (tiny-scale) analogue
//! suite, all validated for validity + maximality, plus cross-algorithm
//! sanity (any two maximal matchings are within 2× in size).

use skipper::coordinator::datasets::{generate, Scale, SUITE};
use skipper::graph::builder::{build, relabel, to_edge_list, BuildOptions};
use skipper::matching::ems::auer_bisseling::AuerBisseling;
use skipper::matching::ems::birn::Birn;
use skipper::matching::ems::idmm::Idmm;
use skipper::matching::ems::israeli_itai::IsraeliItai;
use skipper::matching::ems::pbmm::Pbmm;
use skipper::matching::ems::sidmm::Sidmm;
use skipper::matching::sgmm::Sgmm;
use skipper::matching::skipper::Skipper;
use skipper::matching::{verify, MaximalMatcher, Matching};
use skipper::util::rng::Xoshiro256pp;

fn algorithms() -> Vec<Box<dyn MaximalMatcher>> {
    vec![
        Box::new(Sgmm),
        Box::new(Skipper::new(1)),
        Box::new(Skipper::new(4)),
        Box::new(Sidmm::default()),
        Box::new(Idmm::default()),
        Box::new(Pbmm::default()),
        Box::new(IsraeliItai::default()),
        Box::new(Birn::default()),
        Box::new(AuerBisseling::default()),
    ]
}

#[test]
fn every_algorithm_on_every_suite_dataset() {
    for spec in &SUITE {
        let g = generate(spec, Scale::Tiny);
        let mut sizes: Vec<(String, usize)> = Vec::new();
        for algo in algorithms() {
            let m = algo.run(&g);
            verify::check(&g, &m)
                .unwrap_or_else(|e| panic!("{} invalid on {}: {e}", algo.name(), spec.name));
            sizes.push((algo.name(), m.len()));
        }
        // maximal matchings are 2-approximations of each other
        let max = sizes.iter().map(|(_, s)| *s).max().unwrap();
        let min = sizes.iter().map(|(_, s)| *s).min().unwrap();
        assert!(
            min * 2 >= max,
            "matching sizes diverge on {}: {:?}",
            spec.name,
            sizes
        );
    }
}

#[test]
fn skipper_thread_counts_agree_on_size_band() {
    let g = generate(&SUITE[1], Scale::Tiny); // g500s
    let baseline = Skipper::new(1).run(&g).len();
    for t in [2, 4, 8, 16] {
        let m = Skipper::new(t).run(&g);
        verify::check(&g, &m).unwrap();
        let ratio = m.len() as f64 / baseline as f64;
        assert!((0.9..1.12).contains(&ratio), "t={t} ratio {ratio}");
    }
}

#[test]
fn vertex_relabeling_preserves_validity() {
    // Skipper's correctness is ordering-independent (§VI-A).
    let g = generate(&SUITE[0], Scale::Tiny);
    let mut rng = Xoshiro256pp::new(77);
    let perm = rng.permutation(g.num_vertices());
    let g2 = relabel(&g, &perm);
    for algo in algorithms() {
        let m = algo.run(&g2);
        verify::check(&g2, &m).unwrap_or_else(|e| panic!("{} on relabeled: {e}", algo.name()));
    }
}

#[test]
fn skipper_on_directed_nonsymmetric_suite_inputs() {
    // §V-C: no symmetrization required for Skipper.
    for spec in SUITE.iter().take(3) {
        let sym = generate(spec, Scale::Tiny);
        let el = to_edge_list(&sym);
        let directed = build(
            &el,
            BuildOptions {
                symmetrize: false,
                dedup: true,
                drop_self_loops: true,
            },
        );
        let m = Skipper::new(4).run(&directed);
        verify::check(&sym, &m)
            .unwrap_or_else(|e| panic!("directed skipper invalid on {}: {e}", spec.name));
    }
}

#[test]
fn deterministic_algorithms_are_deterministic() {
    let g = generate(&SUITE[2], Scale::Tiny);
    let pairs: Vec<Box<dyn MaximalMatcher>> = vec![
        Box::new(Sgmm),
        Box::new(Idmm::default()),
        Box::new(Sidmm::default()),
        Box::new(Pbmm::default()),
    ];
    for a in pairs {
        let ma = a.run(&g);
        let mb = a.run(&g);
        assert_eq!(ma.to_sorted_vec(), mb.to_sorted_vec(), "{}", a.name());
    }
}

#[test]
fn skipper_output_buffers_have_sentinel_structure() {
    let g = generate(&SUITE[3], Scale::Tiny);
    let m: Matching = Skipper::new(4).run(&g);
    // arena slots are a whole number of 1024-edge buffers
    assert_eq!(m.slots_used() % skipper::matching::BUFFER_EDGES, 0);
    // iterator yields exactly len() pairs
    assert_eq!(m.iter().count(), m.len());
}

#[test]
fn maximality_violation_counter_agrees_with_checker() {
    let g = generate(&SUITE[4], Scale::Tiny);
    let m = Skipper::new(2).run(&g);
    assert_eq!(verify::count_maximality_violations(&g, &m, 2), 0);
    let empty = Matching::from_pairs(vec![]);
    assert!(verify::count_maximality_violations(&g, &empty, 2) > 0);
}
