//! Property tests for the adjacency sidecar's storage layouts: for random
//! insert/delete schedules, the flat per-vertex `Vec` layout and the
//! cache-line block arena at several block sizes must be *observationally
//! identical* — same accept/reject result for every operation, same live
//! edge set, same per-vertex neighbor sequences (slot order is part of the
//! contract, not just set equality), same half-edge counts — and both must
//! agree with an independently maintained `HashSet` model.
//!
//! A second suite replays engine-level churn schedules on
//! [`ShardedDynamicMatcher`] built flat vs blocked at `P ∈ {1, 4}`: the
//! layouts must drive the engine to the identical live edge set and a
//! verified-maximal matching at every shard count.

use skipper::dynamic::{AdjLayout, DynamicAdjacency, ShardExec, ShardedDynamicMatcher, Update};
use skipper::graph::gen::erdos_renyi;
use skipper::instrument::NoProbe;
use skipper::util::qcheck::{check, Config};
use skipper::util::rng::Xoshiro256pp;
use skipper::VertexId;
use std::collections::HashSet;

/// Block sizes the arena is exercised at alongside the flat baseline.
const LAYOUTS: [AdjLayout; 4] = [
    AdjLayout::Flat,
    AdjLayout::Blocked { block_bytes: 64 },
    AdjLayout::Blocked { block_bytes: 128 },
    AdjLayout::Blocked { block_bytes: 256 },
];

#[derive(Clone, Debug)]
struct AdjSchedule {
    n: usize,
    /// `(u, v, is_delete)` operations, self-loops and out-of-range included
    /// on purpose — rejects must agree across layouts too.
    ops: Vec<(VertexId, VertexId, bool)>,
}

fn arb_adj_schedule(rng: &mut Xoshiro256pp) -> AdjSchedule {
    let n = 4 + rng.next_usize(120);
    let len = 50 + rng.next_usize(900);
    // skewed endpoint choice concentrates churn on a few hot vertices so
    // lists grow past one block and tombstone-driven compaction triggers
    let hot = rng.next_usize(n) as VertexId;
    let ops = (0..len)
        .map(|_| {
            let u = if rng.next_usize(3) == 0 { hot } else { rng.next_usize(n) as VertexId };
            let v = rng.next_usize(n + 2) as VertexId; // may be out of range
            (u, v, rng.next_usize(100) < 40)
        })
        .collect();
    AdjSchedule { n, ops }
}

fn canon(u: VertexId, v: VertexId) -> (VertexId, VertexId) {
    (u.min(v), u.max(v))
}

/// Replay the schedule against every layout and a `HashSet` model in
/// lock-step; error on the first observable divergence.
fn run_adj_schedule(s: &AdjSchedule) -> Result<(), String> {
    let mut sides: Vec<DynamicAdjacency> =
        LAYOUTS.iter().map(|&l| DynamicAdjacency::with_layout(s.n, l)).collect();
    let mut model: HashSet<(VertexId, VertexId)> = HashSet::new();

    for (k, &(u, v, del)) in s.ops.iter().enumerate() {
        let in_range = u != v && (u as usize) < s.n && (v as usize) < s.n;
        let want = if del {
            in_range && model.remove(&canon(u, v))
        } else {
            in_range && model.insert(canon(u, v))
        };
        for (side, &layout) in sides.iter_mut().zip(LAYOUTS.iter()) {
            let got = if del { side.delete(u, v) } else { side.insert(u, v) };
            if got != want {
                return Err(format!(
                    "op {k} ({u},{v},del={del}): {} returned {got}, model says {want}",
                    layout.name()
                ));
            }
        }
        for (side, &layout) in sides.iter().zip(LAYOUTS.iter()) {
            if side.num_live_edges() != model.len() as u64 {
                return Err(format!(
                    "op {k}: {} live {} != model {}",
                    layout.name(),
                    side.num_live_edges(),
                    model.len()
                ));
            }
        }
    }

    // final live edge sets: every layout == model
    let mut want: Vec<(VertexId, VertexId)> = model.iter().copied().collect();
    want.sort_unstable();
    for (side, &layout) in sides.iter().zip(LAYOUTS.iter()) {
        let mut got: Vec<(VertexId, VertexId)> = side.live_edge_iter().collect();
        got.sort_unstable();
        if got != want {
            return Err(format!("{}: final live edge set diverges from model", layout.name()));
        }
        // the probe sweep walks every live half-edge exactly once
        let visited = side.probe_sweep(&mut NoProbe);
        if visited != 2 * model.len() as u64 {
            return Err(format!(
                "{}: probe_sweep visited {visited} half-edges, expected {}",
                layout.name(),
                2 * model.len()
            ));
        }
    }

    // slot order is part of the contract: identical neighbor *sequences*
    // across layouts for every vertex, not just set equality
    let flat = &sides[0];
    for v in 0..s.n as VertexId {
        let want_seq: Vec<VertexId> = flat.live_neighbors(v).collect();
        for (side, &layout) in sides.iter().zip(LAYOUTS.iter()).skip(1) {
            let got_seq: Vec<VertexId> = side.live_neighbors(v).collect();
            if got_seq != want_seq {
                return Err(format!(
                    "vertex {v}: {} neighbor order {got_seq:?} != flat {want_seq:?}",
                    layout.name()
                ));
            }
        }
    }
    Ok(())
}

#[test]
fn layouts_are_observationally_identical_on_random_schedules() {
    check(
        &Config { cases: 60, ..Default::default() },
        arb_adj_schedule,
        run_adj_schedule,
    );
}

#[test]
fn delete_heavy_schedules_compact_without_diverging() {
    // 80%+ deletes against a pre-populated universe: tombstones dominate
    // quickly, so compaction (and block recycling in the arena) fires on
    // the hot vertices while the model keeps the layouts honest
    check(
        &Config { cases: 30, seed: 0xB10C, ..Default::default() },
        |rng| {
            let mut s = arb_adj_schedule(rng);
            let n = s.n;
            let el = erdos_renyi::edges(n, 4 * n, rng.next_u64());
            let mut pre: Vec<(VertexId, VertexId, bool)> = el
                .edges
                .iter()
                .filter(|&&(u, v)| u != v)
                .map(|&(u, v)| (u, v, false))
                .collect();
            for op in s.ops.iter_mut() {
                op.2 = rng.next_usize(100) < 80;
            }
            pre.append(&mut s.ops);
            s.ops = pre;
            s
        },
        run_adj_schedule,
    );
}

#[derive(Clone, Debug)]
struct EngineSchedule {
    n: usize,
    population: Vec<(VertexId, VertexId)>,
    epochs: usize,
    batch: usize,
    seed: u64,
}

fn arb_engine_schedule(rng: &mut Xoshiro256pp) -> EngineSchedule {
    let n = 32 + rng.next_usize(300);
    let el = erdos_renyi::edges(n, 3 * n + rng.next_usize(3 * n), rng.next_u64());
    let mut population: Vec<(VertexId, VertexId)> = el
        .edges
        .iter()
        .filter(|&&(u, v)| u != v)
        .map(|&(u, v)| (u.min(v), u.max(v)))
        .collect();
    population.sort_unstable();
    population.dedup();
    rng.shuffle(&mut population);
    EngineSchedule {
        n,
        population,
        epochs: 2 + rng.next_usize(6),
        batch: 10 + rng.next_usize(150),
        seed: rng.next_u64(),
    }
}

/// Replay the identical update stream on engines built with each layout at
/// one shard count; live sets must agree exactly and every engine's own
/// maximality audit must pass after every epoch.
fn run_engine_schedule_at(s: &EngineSchedule, shards: usize) -> Result<(), String> {
    let engines: Vec<(AdjLayout, ShardedDynamicMatcher)> =
        [AdjLayout::Flat, AdjLayout::Blocked { block_bytes: 64 }]
            .into_iter()
            .map(|l| {
                (l, ShardedDynamicMatcher::with_exec_layout(s.n, 2, shards, ShardExec::Pool, l))
            })
            .collect();
    let mut rng = Xoshiro256pp::new(s.seed);
    let mut live: Vec<(VertexId, VertexId)> = Vec::new();
    let mut pool = s.population.clone();

    for epoch in 0..s.epochs {
        let mut updates = Vec::with_capacity(s.batch);
        for _ in 0..s.batch {
            if !live.is_empty() && rng.next_usize(100) < 45 {
                let (u, v) = live.swap_remove(rng.next_usize(live.len()));
                pool.push((u, v));
                updates.push(Update::Delete(u, v));
            } else if let Some((u, v)) = pool.pop() {
                live.push((u, v));
                updates.push(Update::Insert(u, v));
            }
        }
        let mut want = live.clone();
        want.sort_unstable();
        for (layout, engine) in &engines {
            engine
                .apply_epoch(&updates)
                .map_err(|e| format!("P={shards} {} epoch {epoch}: {e}", layout.name()))?;
            engine
                .verify()
                .map_err(|e| format!("P={shards} {} epoch {epoch}: audit: {e}", layout.name()))?;
            let mut got = engine.live_edges();
            got.sort_unstable();
            if got != want {
                return Err(format!(
                    "P={shards} {} epoch {epoch}: live edge set diverges from model",
                    layout.name()
                ));
            }
        }
    }
    Ok(())
}

#[test]
fn engine_layouts_agree_on_random_churn_at_every_shard_count() {
    check(
        &Config { cases: 20, seed: 0xAD7E, ..Default::default() },
        arb_engine_schedule,
        |s| {
            for shards in [1usize, 4] {
                run_engine_schedule_at(s, shards)?;
            }
            Ok(())
        },
    );
}
