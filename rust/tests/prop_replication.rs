//! Kill-9 failover property tests for the replication subsystem.
//!
//! For random churn schedules at `engine_shards ∈ {1, 4}`:
//!
//! * a **primary** (an engine plus a [`Shipper`], driven exactly the way
//!   the service flusher drives them: apply locally, then publish) streams
//!   committed epochs to two warm standbys — one durable, one volatile;
//! * at **quiesce** (both followers caught up) every follower answers
//!   `partner(v)` identically to the primary for every vertex — the engine
//!   is deterministic for a fixed config, so replaying the same epoch
//!   sequence converges to bit-identical `partner[]` state;
//! * the primary is then **killed** after an arbitrary epoch — its sockets
//!   close with no goodbye, indistinguishable from `kill -9`;
//! * **failover** promotes the follower with the longest contiguous log
//!   (= highest applied epoch; the stream is contiguous and gap-free).
//!   The promoted node must hold a live-edge set *identical* to the
//!   model's at the kill point, a matching the HashSet live-graph model
//!   confirms maximal, and an epoch counter at least the highest epoch any
//!   follower had acked when the primary died — zero acked epochs lost;
//! * the promoted node then **keeps writing**: the next schedule epoch
//!   applies through [`Replica::apply_updates`] and the result again
//!   matches the model exactly, while the losing follower stays read-only.
//!
//! A separate deterministic test drives the follower *front end*
//! (`serve_follower_lines`): writes are structured errors until `PROMOTE`,
//! then the full write path works; and a durable follower killed and
//! restarted recovers from its own WAL, then resumes the stream right
//! where recovery left off.

use skipper::dynamic::{ShardedDynamicMatcher, Update};
use skipper::matching::verify::verify_maximal_dynamic;
use skipper::obs::metrics;
use skipper::persist::ship::Shipper;
use skipper::service::{serve_follower_lines, Replica, ServiceConfig};
use skipper::util::qcheck::{check, Config};
use skipper::util::rng::Xoshiro256pp;
use skipper::VertexId;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

static DIR_ID: AtomicU64 = AtomicU64::new(0);

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "skipper_prop_replication_{}_{}_{}",
        std::process::id(),
        tag,
        DIR_ID.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn loopback_available() -> bool {
    std::net::TcpListener::bind("127.0.0.1:0").is_ok()
}

/// A concrete random schedule: per-epoch update batches plus the model's
/// live-edge set after each epoch (maintained with disjoint live/pool/dead
/// vectors, so the model is trivially exact). `kill_after` is strictly
/// less than `epochs.len()`, so there is always at least one post-failover
/// batch for the promoted node to write.
#[derive(Clone, Debug)]
struct Schedule {
    n: usize,
    epochs: Vec<Vec<Update>>,
    live_after: Vec<Vec<(VertexId, VertexId)>>,
    /// Kill the primary after this many epochs (1-based count).
    kill_after: usize,
}

fn arb_schedule(rng: &mut Xoshiro256pp) -> Schedule {
    let n = 16 + rng.next_usize(180);
    let num_epochs = 3 + rng.next_usize(7);
    let batch = 4 + rng.next_usize(60);
    let mut pool: Vec<(VertexId, VertexId)> = Vec::new();
    for u in 0..n as VertexId {
        for _ in 0..3 {
            let v = rng.next_usize(n) as VertexId;
            if u != v {
                let e = (u.min(v), u.max(v));
                if !pool.contains(&e) {
                    pool.push(e);
                }
            }
        }
    }
    rng.shuffle(&mut pool);
    let mut live: Vec<(VertexId, VertexId)> = Vec::new();
    let mut dead: Vec<(VertexId, VertexId)> = Vec::new();
    let mut epochs = Vec::new();
    let mut live_after = Vec::new();
    for _ in 0..num_epochs {
        let mut ups = Vec::with_capacity(batch);
        for _ in 0..batch {
            let deleting = !live.is_empty() && rng.next_usize(100) < 40;
            if deleting {
                let i = rng.next_usize(live.len());
                let (u, v) = live.swap_remove(i);
                dead.push((u, v));
                ups.push(Update::Delete(u, v));
            } else {
                if pool.is_empty() {
                    pool.append(&mut dead);
                    rng.shuffle(&mut pool);
                }
                match pool.pop() {
                    Some((u, v)) => {
                        live.push((u, v));
                        ups.push(Update::Insert(u, v));
                    }
                    None => break,
                }
            }
        }
        if ups.is_empty() {
            // never ship an empty epoch — the real service coalesces those
            // into EpochIdle and applies nothing
            let i = rng.next_usize(live.len());
            let (u, v) = live.swap_remove(i);
            dead.push((u, v));
            ups.push(Update::Delete(u, v));
        }
        epochs.push(ups);
        let mut snap = live.clone();
        snap.sort_unstable();
        live_after.push(snap);
    }
    let kill_after = 1 + rng.next_usize(epochs.len() - 1);
    Schedule { n, epochs, live_after, kill_after }
}

/// Poll until a replica's replay loop has exited, or fail with `what`.
fn wait_drained(r: &Replica, what: &str) -> Result<(), String> {
    let deadline = Instant::now() + Duration::from_secs(10);
    while r.replaying() {
        if Instant::now() >= deadline {
            return Err(format!("{what}: replay loop still running after primary death"));
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    Ok(())
}

/// Run the kill-9 failover life at one shard count.
fn kill_and_fail_over(s: &Schedule, shards: usize) -> Result<(), String> {
    let tag = |m: String| format!("P={shards}: {m}");
    let dir = fresh_dir("failover");

    // The primary: its engine plus the replication listener, fed the way
    // the service flusher feeds them — apply locally, then publish. The
    // engine config (pool exec, default layout, unpinned) matches what
    // Replica::new builds from a default ServiceConfig, so follower state
    // must converge bit-identically.
    let primary = ShardedDynamicMatcher::new(s.n, 2, shards);
    let reg = metrics::Registry::new();
    let shipper = Shipper::bind("127.0.0.1:0", s.n, 0, &reg).map_err(&tag)?;
    let addr = shipper.local_addr().to_string();

    let durable_cfg = ServiceConfig {
        num_vertices: s.n,
        threads: 2,
        engine_shards: shards,
        data_dir: Some(dir.to_string_lossy().into_owned()),
        ..Default::default()
    };
    let volatile_cfg =
        ServiceConfig { num_vertices: s.n, threads: 2, engine_shards: shards, ..Default::default() };
    let followers =
        [Replica::new(&durable_cfg, &addr)?, Replica::new(&volatile_cfg, &addr)?];

    let killed_at = s.kill_after as u64;
    let result = std::thread::scope(|sc| {
        for f in &followers {
            sc.spawn(move || f.replay_loop());
        }
        let body = || -> Result<(), String> {
            for (i, ups) in s.epochs.iter().take(s.kill_after).enumerate() {
                primary.apply_epoch(ups)?;
                shipper.publish(i as u64 + 1, ups);
            }

            // quiesce: both followers catch up
            for (fi, f) in followers.iter().enumerate() {
                if !f.wait_applied(killed_at, Duration::from_secs(20)) {
                    return Err(format!(
                        "follower {fi} stuck at epoch {} of {killed_at} (error: {:?})",
                        f.applied_epoch(),
                        f.replay_error()
                    ));
                }
            }
            // at quiesce every QUERY answer matches the primary's exactly
            for v in 0..s.n as VertexId {
                for (fi, f) in followers.iter().enumerate() {
                    if f.partner(v) != primary.partner(v) {
                        return Err(format!(
                            "follower {fi}: partner({v}) = {:?} but primary says {:?}",
                            f.partner(v),
                            primary.partner(v)
                        ));
                    }
                }
            }
            // the highest epoch acked by every live follower at the kill;
            // ack intake is asynchronous, so this may trail killed_at —
            // the failover guarantee is "nothing acked is lost"
            let acked_at_kill = shipper.stats().acked;

            // kill -9: sockets close with no goodbye
            shipper.shutdown();
            for (fi, f) in followers.iter().enumerate() {
                wait_drained(f, &format!("follower {fi}"))?;
                if let Some(e) = f.replay_error() {
                    return Err(format!("follower {fi}: primary death read as error: {e}"));
                }
            }

            // failover: longest contiguous log wins (ties → either)
            let (winner, loser) = if followers[0].applied_epoch() >= followers[1].applied_epoch()
            {
                (&followers[0], &followers[1])
            } else {
                (&followers[1], &followers[0])
            };
            let promoted_epoch = winner.promote();
            if promoted_epoch < acked_at_kill {
                return Err(format!(
                    "acked epochs lost: promoted at {promoted_epoch}, primary had acks to {acked_at_kill}"
                ));
            }
            if promoted_epoch != killed_at {
                return Err(format!(
                    "both followers had quiesced at {killed_at} but promotion reports {promoted_epoch}"
                ));
            }
            if winner.promote() != promoted_epoch {
                return Err("second PROMOTE was not an idempotent no-op".into());
            }

            // the promoted node's state is exactly the model's at the kill
            let model = &s.live_after[s.kill_after - 1];
            let mut got = winner.engine().live_edges();
            got.sort_unstable();
            if &got != model {
                return Err(format!(
                    "promoted live set diverged: {} edges vs model {}",
                    got.len(),
                    model.len()
                ));
            }
            verify_maximal_dynamic(s.n, model.iter().copied(), &winner.engine().matching_pairs())
                .map_err(|e| format!("promoted matching not maximal: {e}"))?;

            // the loser is still a read-only standby
            if loser.is_promoted() {
                return Err("losing follower reports itself promoted".into());
            }
            if loser.apply_updates(&s.epochs[s.kill_after]).is_ok() {
                return Err("losing follower accepted a write without PROMOTE".into());
            }

            // life goes on: the promoted node writes the next epoch and
            // still matches the model exactly
            let report = winner.apply_updates(&s.epochs[s.kill_after])?;
            if report.epoch != killed_at + 1 {
                return Err(format!(
                    "post-failover epoch numbered {} instead of {}",
                    report.epoch,
                    killed_at + 1
                ));
            }
            let model = &s.live_after[s.kill_after];
            let mut got = winner.engine().live_edges();
            got.sort_unstable();
            if &got != model {
                return Err(format!(
                    "post-failover live set diverged: {} edges vs model {}",
                    got.len(),
                    model.len()
                ));
            }
            verify_maximal_dynamic(s.n, model.iter().copied(), &winner.engine().matching_pairs())
                .map_err(|e| format!("post-failover matching not maximal: {e}"))?;
            winner.verify().map_err(|e| format!("promoted audit failed: {e}"))?;
            Ok(())
        };
        let r = body();
        // wind down no matter what, so the scope can join the replay loops
        shipper.shutdown();
        for f in &followers {
            f.disconnect();
        }
        r.map_err(&tag)
    });
    let _ = std::fs::remove_dir_all(&dir);
    result
}

#[test]
fn kill9_failover_loses_no_acked_epoch_and_stays_maximal() {
    if !loopback_available() {
        eprintln!("skipping kill9_failover_loses_no_acked_epoch_and_stays_maximal: no loopback");
        return;
    }
    check(
        &Config { cases: 10, seed: 0x5A1F, max_shrink_steps: 0 },
        arb_schedule,
        |s| {
            for shards in [1usize, 4] {
                kill_and_fail_over(s, shards)?;
            }
            Ok(())
        },
    );
}

/// A durable follower that dies and restarts recovers from its own WAL,
/// then resumes the stream exactly where recovery left off — no replayed
/// epoch is fetched twice, no shipped epoch is skipped.
#[test]
fn durable_follower_restart_resumes_stream_where_recovery_left_off() {
    if !loopback_available() {
        eprintln!(
            "skipping durable_follower_restart_resumes_stream_where_recovery_left_off: no loopback"
        );
        return;
    }
    let mut rng = Xoshiro256pp::new(0x5EED);
    for case in 0..4 {
        let s = arb_schedule(&mut rng);
        let dir = fresh_dir("resume");
        let cfg = ServiceConfig {
            num_vertices: s.n,
            threads: 2,
            engine_shards: 4,
            data_dir: Some(dir.to_string_lossy().into_owned()),
            ..Default::default()
        };
        let reg = metrics::Registry::new();
        let shipper = Shipper::bind("127.0.0.1:0", s.n, 0, &reg).unwrap();
        let addr = shipper.local_addr().to_string();
        let split = s.kill_after;

        // life 1: replay the first `split` epochs, then die cold — no
        // finish(), no final snapshot; the WAL alone carries the state
        let r1 = Replica::new(&cfg, &addr).unwrap();
        std::thread::scope(|sc| {
            sc.spawn(|| r1.replay_loop());
            for (i, ups) in s.epochs.iter().take(split).enumerate() {
                shipper.publish(i as u64 + 1, ups);
            }
            assert!(
                r1.wait_applied(split as u64, Duration::from_secs(20)),
                "case {case}: follower stuck at {} of {split} ({:?})",
                r1.applied_epoch(),
                r1.replay_error()
            );
            r1.disconnect();
        });
        drop(r1);

        // the primary keeps committing while the follower is down
        for (i, ups) in s.epochs.iter().enumerate().skip(split) {
            shipper.publish(i as u64 + 1, ups);
        }

        // life 2: recovery replays the local WAL to `split`, the handshake
        // resumes after it, and the stream delivers only `split+1..`
        let r2 = Replica::new(&cfg, &addr).unwrap();
        std::thread::scope(|sc| {
            sc.spawn(|| r2.replay_loop());
            assert!(
                r2.wait_applied(s.epochs.len() as u64, Duration::from_secs(20)),
                "case {case}: restarted follower stuck at {} of {} ({:?})",
                r2.applied_epoch(),
                s.epochs.len(),
                r2.replay_error()
            );
            shipper.shutdown();
            r2.disconnect();
        });
        let mut got = r2.engine().live_edges();
        got.sort_unstable();
        assert_eq!(got, *s.live_after.last().unwrap(), "case {case}: final live set");
        verify_maximal_dynamic(s.n, got.iter().copied(), &r2.engine().matching_pairs())
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        drop(r2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// The follower front end, deterministically (the primary publishes
/// nothing, so there is no replication race): every write is a structured
/// error until `PROMOTE`, after which the full write path works and
/// `STATS` reports the promoted role.
#[test]
fn follower_front_end_is_read_only_until_promote_then_writable() {
    if !loopback_available() {
        eprintln!("skipping follower_front_end_is_read_only_until_promote_then_writable: no loopback");
        return;
    }
    let reg = metrics::Registry::new();
    let shipper = Shipper::bind("127.0.0.1:0", 64, 0, &reg).unwrap();
    let addr = shipper.local_addr().to_string();
    let cfg = ServiceConfig { num_vertices: 64, threads: 1, engine_shards: 4, ..Default::default() };
    let script = "\
INSERT 0 1\n\
EPOCH\n\
SNAPSHOT\n\
PROMOTE\n\
INSERT 0 1 2 3\n\
EPOCH\n\
QUERY 0\n\
STATS full\n\
METRICS\n\
QUIT\n";
    let mut out = Vec::new();
    let summary = serve_follower_lines(&cfg, &addr, script.as_bytes(), &mut out).unwrap();
    shipper.shutdown();
    let text = String::from_utf8(out).unwrap();
    let mut lines = text.lines();
    let mut next = || lines.next().unwrap().to_string();

    let l = next();
    assert!(l.contains(r#""ok":false"#) && l.contains("read-only follower"), "INSERT: {l}");
    let l = next();
    assert!(l.contains(r#""ok":false"#) && l.contains("read-only follower"), "EPOCH: {l}");
    let l = next();
    assert!(l.contains("SNAPSHOT requires --data-dir"), "SNAPSHOT: {l}");
    let l = next();
    assert_eq!(l, r#"{"ok":true,"op":"promote","epoch":0}"#, "PROMOTE");
    let l = next();
    assert_eq!(l, r#"{"ok":true,"op":"queued","count":2}"#, "post-promote INSERT");
    let l = next();
    assert!(l.contains(r#""op":"epoch""#) && l.contains(r#""epoch":1"#), "post-promote EPOCH: {l}");
    let l = next();
    assert!(l.contains(r#""matched":true"#) && l.contains(r#""partner":1"#), "QUERY: {l}");
    let l = next();
    assert!(l.contains(r#""replica_role":"promoted""#), "STATS: {l}");
    assert!(l.contains(r#""epochs":1"#) && l.contains(r#""live_edges":2"#), "STATS: {l}");
    assert!(l.contains(r#""replica_lag_epochs":0"#), "STATS: {l}");
    assert!(l.contains(r#""maximal":true"#), "STATS full: {l}");
    // the METRICS exposition spans lines; the replica gauge must be in it
    assert!(text.contains("skipper_replica_lag_epochs"), "METRICS: {text}");
    assert!(text.contains("# EOF"), "METRICS framing: {text}");

    assert!(summary.promoted, "summary: {summary:?}");
    assert_eq!(summary.epochs, 1, "summary: {summary:?}");
    assert_eq!(summary.live_edges, 2, "summary: {summary:?}");
    assert!(summary.maximal, "summary: {summary:?}");
}

/// Universe-size mismatches are refused at the handshake, loudly.
#[test]
fn mismatched_universe_is_refused_at_connect() {
    if !loopback_available() {
        eprintln!("skipping mismatched_universe_is_refused_at_connect: no loopback");
        return;
    }
    let reg = metrics::Registry::new();
    let shipper = Shipper::bind("127.0.0.1:0", 128, 0, &reg).unwrap();
    let addr = shipper.local_addr().to_string();
    let cfg = ServiceConfig { num_vertices: 32, threads: 1, engine_shards: 1, ..Default::default() };
    let err = Replica::new(&cfg, &addr).unwrap_err();
    assert!(err.contains("universes must match"), "{err}");
    shipper.shutdown();
}
