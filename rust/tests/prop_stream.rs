//! Property tests for the streaming ingest→match pipeline: for arbitrary
//! random graphs, matching over ANY chunking and ANY permutation of the
//! edge stream must verify as a valid maximal matching against the
//! materialized union graph — i.e. the chunk driver is behaviorally
//! interchangeable with the CSR driver, for every delivery order.

use skipper::graph::builder::{build, to_edge_list, BuildOptions};
use skipper::graph::gen::{rmat, GenConfig};
use skipper::graph::stream::{BatchEdgeSource, CsrEdgeSource};
use skipper::graph::EdgeList;
use skipper::matching::streaming::StreamingSkipper;
use skipper::matching::{verify, MaximalMatcher};
use skipper::util::qcheck::{check, Config};
use skipper::util::rng::Xoshiro256pp;
use skipper::VertexId;

#[derive(Clone, Debug)]
struct StreamCase {
    n: usize,
    edges: Vec<(VertexId, VertexId)>,
    chunk_edges: usize,
    threads: usize,
}

/// Random multigraph (self-loops and duplicates allowed) with a random
/// stream permutation, chunk size, and consumer count.
fn arb_case(rng: &mut Xoshiro256pp) -> StreamCase {
    let n = 2 + rng.next_usize(500);
    let m = rng.next_usize(4 * n + 1);
    let mut edges: Vec<(VertexId, VertexId)> = (0..m)
        .map(|_| (rng.next_usize(n) as VertexId, rng.next_usize(n) as VertexId))
        .collect();
    rng.shuffle(&mut edges);
    StreamCase {
        n,
        edges,
        chunk_edges: 1 + rng.next_usize(300),
        threads: 1 + rng.next_usize(4),
    }
}

fn union_graph(n: usize, edges: &[(VertexId, VertexId)]) -> skipper::graph::CsrGraph {
    let mut el = EdgeList::new(n);
    for &(u, v) in edges {
        el.push(u, v);
    }
    build(&el, BuildOptions::default())
}

#[test]
fn any_chunking_and_permutation_is_maximal_on_the_union_graph() {
    check(
        &Config { cases: 48, ..Default::default() },
        arb_case,
        |case| {
            let sk = StreamingSkipper::new(case.threads).with_chunk_edges(case.chunk_edges);
            let rep = sk
                .run(BatchEdgeSource::new(case.n, &case.edges))
                .map_err(|e| format!("stream run failed: {e}"))?;
            if rep.edges_streamed != case.edges.len() as u64 {
                return Err(format!(
                    "streamed {} of {} edges",
                    rep.edges_streamed,
                    case.edges.len()
                ));
            }
            let g = union_graph(case.n, &case.edges);
            verify::check(&g, &rep.matching)
                .map_err(|e| format!("chunk={} t={}: {e}", case.chunk_edges, case.threads))
        },
    );
}

#[test]
fn streamed_and_csr_drivers_agree_on_size_band() {
    // both drivers are maximal on the same graph, so sizes are within 2x
    check(
        &Config { cases: 24, ..Default::default() },
        |rng| {
            let scale = 7 + rng.next_usize(3) as u32;
            let g = rmat::generate(&GenConfig {
                scale,
                avg_degree: 2 + rng.next_usize(7) as u32,
                seed: rng.next_u64(),
            });
            (g, 1 + rng.next_usize(3))
        },
        |(g, threads)| {
            let csr_m = skipper::matching::skipper::Skipper::new(*threads).run(g);
            let rep = StreamingSkipper::new(*threads)
                .with_chunk_edges(777)
                .run(CsrEdgeSource::new(g))
                .map_err(|e| format!("stream run failed: {e}"))?;
            verify::check(g, &rep.matching).map_err(|e| format!("streamed: {e}"))?;
            let (a, b) = (csr_m.len().max(1), rep.matching.len().max(1));
            if a * 2 < b || b * 2 < a {
                return Err(format!("sizes diverge: csr {a} vs stream {b}"));
            }
            Ok(())
        },
    );
}

#[test]
fn canonical_edge_stream_of_a_real_graph_is_maximal() {
    // stream each undirected edge exactly once (canonical u<v order),
    // randomly permuted — the non-symmetrized single-copy regime of §V-C
    check(
        &Config { cases: 16, ..Default::default() },
        |rng| {
            let g = rmat::generate(&GenConfig {
                scale: 8,
                avg_degree: 4,
                seed: rng.next_u64(),
            });
            let mut edges = to_edge_list(&g).edges;
            rng.shuffle(&mut edges);
            (g, edges, 1 + rng.next_usize(200))
        },
        |(g, edges, chunk)| {
            let rep = StreamingSkipper::new(2)
                .with_chunk_edges(*chunk)
                .run(BatchEdgeSource::new(g.num_vertices(), edges))
                .map_err(|e| format!("stream run failed: {e}"))?;
            verify::check(g, &rep.matching).map_err(|e| format!("chunk={chunk}: {e}"))
        },
    );
}
