//! Property tests on the APRAM simulator and cost model: the simulated
//! matchings obey the same invariants as real executions, simulation is
//! deterministic, conflict counts scale sanely with thread count, and the
//! cost model is monotone in its inputs.

use skipper::apram::cost::{CostModel, WorkProfile};
use skipper::apram::{simulate_skipper, SimConfig};
use skipper::graph::gen::{erdos_renyi, rmat, GenConfig};
use skipper::graph::CsrGraph;
use skipper::matching::sgmm::Sgmm;
use skipper::matching::{verify, MaximalMatcher};
use skipper::util::qcheck::{check, Config};
use skipper::util::rng::Xoshiro256pp;

fn arb_graph(rng: &mut Xoshiro256pp) -> CsrGraph {
    if rng.next_u64() & 1 == 0 {
        let n = 32 + rng.next_usize(600);
        erdos_renyi::generate(n, n * (1 + rng.next_usize(6)), rng.next_u64())
    } else {
        rmat::generate(&GenConfig {
            scale: 6 + rng.next_usize(4) as u32,
            avg_degree: 2 + rng.next_usize(8) as u32,
            seed: rng.next_u64(),
        })
    }
}

fn cfg(cases: usize, seed: u64) -> Config {
    Config {
        cases,
        seed,
        max_shrink_steps: 0,
    }
}

#[test]
fn prop_sim_matchings_valid_maximal() {
    check(&cfg(20, 0xC301), arb_graph, |g| {
        let mut rng = Xoshiro256pp::new(g.num_vertices() as u64);
        let t = 1 + rng.next_usize(64);
        let rep = simulate_skipper(g, &SimConfig::new(t));
        verify::check(g, &rep.matching).map_err(|e| format!("t={t}: {e}"))
    });
}

#[test]
fn prop_sim_deterministic() {
    check(&cfg(12, 0xC302), arb_graph, |g| {
        let c = SimConfig {
            threads: 16,
            blocks_per_thread: 8,
            seed: 99,
        };
        let a = simulate_skipper(g, &c);
        let b = simulate_skipper(g, &c);
        if a.matching.to_sorted_vec() != b.matching.to_sorted_vec()
            || a.per_thread_ops != b.per_thread_ops
        {
            return Err("nondeterministic simulation".into());
        }
        Ok(())
    });
}

#[test]
fn prop_sim_size_band_vs_sgmm() {
    check(&cfg(16, 0xC303), arb_graph, |g| {
        let s = Sgmm.run(g).len();
        let m = simulate_skipper(g, &SimConfig::new(32)).matching.len();
        if s == 0 && m == 0 {
            return Ok(());
        }
        if s * 2 < m || m * 2 < s {
            return Err(format!("sizes {m} vs SGMM {s}"));
        }
        Ok(())
    });
}

#[test]
fn prop_single_vthread_is_conflict_free() {
    check(&cfg(12, 0xC304), arb_graph, |g| {
        let rep = simulate_skipper(g, &SimConfig::new(1));
        if rep.conflicts.total != 0 {
            return Err(format!("t=1 produced {} conflicts", rep.conflicts.total));
        }
        Ok(())
    });
}

#[test]
fn prop_sim_work_linear_in_edges() {
    // §V-B: expected total work O(|E| + |V|).
    check(&cfg(12, 0xC305), arb_graph, |g| {
        let rep = simulate_skipper(g, &SimConfig::new(32));
        let bound = 6 * (g.num_edge_slots() as u64 + g.num_vertices() as u64) + 1000;
        if rep.total_ops() > bound {
            return Err(format!("ops {} > bound {bound}", rep.total_ops()));
        }
        Ok(())
    });
}

#[test]
fn prop_cost_model_monotone() {
    let gen = |rng: &mut Xoshiro256pp| WorkProfile {
        accesses: 1000 + rng.next_below(1_000_000),
        l3_misses: rng.next_below(100_000),
        iterations: rng.next_below(100),
    };
    check(&cfg(50, 0xC306), gen, |p| {
        let m = CostModel::default();
        // more accesses → more time
        let mut p2 = *p;
        p2.accesses += 1_000_000;
        if m.par_seconds(&p2, 8) < m.par_seconds(p, 8) {
            return Err("not monotone in accesses".into());
        }
        // more threads → no slower (given fixed profile)
        if m.par_seconds(p, 64) > m.par_seconds(p, 8) + 1e-12 {
            return Err("more threads made it slower".into());
        }
        // sequential >= parallel
        if m.seq_seconds(p) + 1e-12 < m.par_seconds(p, 1) - p.iterations as f64 * m.barrier_us * 1e-6
        {
            return Err("seq faster than 1-thread parallel".into());
        }
        Ok(())
    });
}

#[test]
fn prop_calibration_reproduces_measurement() {
    let gen = |rng: &mut Xoshiro256pp| {
        (
            0.001 + rng.next_f64() * 10.0,
            WorkProfile {
                accesses: 1_000 + rng.next_below(10_000_000),
                l3_misses: rng.next_below(10_000),
                iterations: 0,
            },
        )
    };
    check(&cfg(50, 0xC307), gen, |(secs, p)| {
        let m = CostModel::calibrated(*secs, p);
        let t = m.seq_seconds(p);
        let rel = (t - secs).abs() / secs;
        // clamped cases (miss-dominated) may deviate; others must match
        if rel > 0.05 && m.ns_per_access > 0.0 {
            let miss_ns = p.l3_misses as f64 * m.l3_miss_penalty_ns * 1e-9;
            if miss_ns < secs * 0.9 {
                return Err(format!("calibration error {rel:.3} (t={t}, want {secs})"));
            }
        }
        Ok(())
    });
}
