//! Kill-and-restart property tests for the durability subsystem.
//!
//! For random churn schedules at `engine_shards ∈ {1, 4}`:
//!
//! * a **durable run** applies each epoch after logging it to the WAL
//!   (optionally snapshotting mid-schedule), then "crashes" after an
//!   arbitrary epoch — everything is dropped with no shutdown ceremony;
//! * **recovery** into a fresh engine (newest snapshot + WAL replay) must
//!   yield a live-edge set *identical* to the uninterrupted run's at the
//!   crash point, a matching the HashSet live-graph model confirms
//!   maximal, and the epoch counter resumed at the crash epoch;
//! * additionally, a random **torn tail** chopped off the WAL must recover
//!   to exactly the live set of some epoch prefix (records are the unit of
//!   atomicity — never half an epoch).
//!
//! The service-level guarantee rides on top: a `serve_lines` session with
//! `--data-dir` that ends gracefully (SHUTDOWN/EOF) writes a final
//! snapshot, and the restarted service recovers from the snapshot alone —
//! zero WAL replay — with the exact matching intact. The real `kill -9`
//! path is exercised end-to-end in `integration_service.rs` and the CI
//! crash-recovery smoke.

use skipper::dynamic::{ShardedDynamicMatcher, Update};
use skipper::matching::verify::verify_maximal_dynamic;
use skipper::persist::recovery;
use skipper::persist::snapshot::{self, SnapshotData};
use skipper::persist::wal::{Wal, WalOptions};
use skipper::service::{serve_lines, ServiceConfig};
use skipper::util::qcheck::{check, Config};
use skipper::util::rng::Xoshiro256pp;
use skipper::VertexId;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

static DIR_ID: AtomicU64 = AtomicU64::new(0);

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "skipper_prop_persist_{}_{}_{}",
        std::process::id(),
        tag,
        DIR_ID.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A concrete random schedule: per-epoch update batches plus the model's
/// live-edge set after each epoch (maintained with disjoint live/pool/dead
/// vectors, so the model is trivially exact).
#[derive(Clone, Debug)]
struct Schedule {
    n: usize,
    epochs: Vec<Vec<Update>>,
    live_after: Vec<Vec<(VertexId, VertexId)>>,
    /// Crash after this many epochs (1-based count, ≤ epochs.len()).
    crash_after: usize,
    /// Snapshot after this epoch index (0-based), if any.
    snapshot_after: Option<usize>,
}

fn arb_schedule(rng: &mut Xoshiro256pp) -> Schedule {
    let n = 16 + rng.next_usize(180);
    let num_epochs = 2 + rng.next_usize(8);
    let batch = 4 + rng.next_usize(60);
    let mut pool: Vec<(VertexId, VertexId)> = Vec::new();
    for u in 0..n as VertexId {
        for _ in 0..3 {
            let v = rng.next_usize(n) as VertexId;
            if u != v {
                let e = (u.min(v), u.max(v));
                if !pool.contains(&e) {
                    pool.push(e);
                }
            }
        }
    }
    rng.shuffle(&mut pool);
    let mut live: Vec<(VertexId, VertexId)> = Vec::new();
    let mut dead: Vec<(VertexId, VertexId)> = Vec::new();
    let mut epochs = Vec::new();
    let mut live_after = Vec::new();
    for _ in 0..num_epochs {
        let mut ups = Vec::with_capacity(batch);
        for _ in 0..batch {
            let deleting = !live.is_empty() && rng.next_usize(100) < 40;
            if deleting {
                let i = rng.next_usize(live.len());
                let (u, v) = live.swap_remove(i);
                dead.push((u, v));
                ups.push(Update::Delete(u, v));
            } else {
                if pool.is_empty() {
                    pool.append(&mut dead);
                    rng.shuffle(&mut pool);
                }
                match pool.pop() {
                    Some((u, v)) => {
                        live.push((u, v));
                        ups.push(Update::Insert(u, v));
                    }
                    None => break,
                }
            }
        }
        epochs.push(ups);
        let mut snap = live.clone();
        snap.sort_unstable();
        live_after.push(snap);
    }
    let crash_after = 1 + rng.next_usize(epochs.len());
    let snapshot_after = if rng.next_usize(2) == 0 {
        Some(rng.next_usize(crash_after))
    } else {
        None
    };
    Schedule { n, epochs, live_after, crash_after, snapshot_after }
}

/// Run the durable life up to the crash point, then recover and check the
/// acceptance properties at one shard count.
fn crash_and_recover(s: &Schedule, shards: usize) -> Result<(), String> {
    let tag = |m: String| format!("P={shards}: {m}");
    let dir = fresh_dir("crash");

    // --- durable life: log each epoch, apply it, maybe snapshot ---------
    {
        let engine = ShardedDynamicMatcher::new(s.n, 2, shards);
        let (mut wal, existing) =
            Wal::open(&recovery::wal_dir(&dir), WalOptions::default())
                .map_err(&tag)?;
        if !existing.is_empty() {
            return Err(tag("fresh wal dir not empty".into()));
        }
        for (i, ups) in s.epochs.iter().take(s.crash_after).enumerate() {
            wal.append_epoch(i as u64 + 1, ups).map_err(&tag)?;
            engine.apply_epoch(ups).map_err(&tag)?;
            if s.snapshot_after == Some(i) {
                let snap_dir = recovery::snapshot_dir(&dir);
                std::fs::create_dir_all(&snap_dir).map_err(|e| tag(e.to_string()))?;
                let data = SnapshotData::capture(&engine);
                snapshot::write_file(
                    &snap_dir.join(snapshot::file_name(data.epoch)),
                    &data,
                )
                .map_err(&tag)?;
            }
        }
    } // crash: wal and engine dropped cold, no final snapshot

    // --- recovery --------------------------------------------------------
    let recovered = ShardedDynamicMatcher::new(s.n, 2, shards);
    let (_wal, report) =
        recovery::recover(&recovered, &dir, WalOptions::default()).map_err(&tag)?;

    let model = &s.live_after[s.crash_after - 1];
    let mut got = recovered.live_edges();
    got.sort_unstable();
    if &got != model {
        return Err(tag(format!(
            "live set diverged after recovery: {} edges vs model {}",
            got.len(),
            model.len()
        )));
    }
    verify_maximal_dynamic(s.n, model.iter().copied(), &recovered.matching_pairs())
        .map_err(|e| tag(format!("recovered matching not maximal: {e}")))?;
    if recovered.epochs_applied() != s.crash_after as u64 {
        return Err(tag(format!(
            "epoch counter resumed at {} instead of {}",
            recovered.epochs_applied(),
            s.crash_after
        )));
    }
    let snap_epoch = s.snapshot_after.map(|i| i as u64 + 1).unwrap_or(0);
    let expect_replayed = s.crash_after as u64 - snap_epoch;
    if report.replayed_epochs != expect_replayed {
        return Err(tag(format!(
            "replayed {} epochs, expected {} (snapshot at {})",
            report.replayed_epochs, expect_replayed, snap_epoch
        )));
    }
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}

#[test]
fn crash_after_arbitrary_epoch_recovers_the_exact_live_set() {
    check(
        &Config { cases: 25, seed: 0xD15C, max_shrink_steps: 0 },
        arb_schedule,
        |s| {
            for shards in [1usize, 4] {
                crash_and_recover(s, shards)?;
            }
            Ok(())
        },
    );
}

#[test]
fn torn_wal_tail_recovers_an_epoch_prefix() {
    // chop random byte counts off the WAL tail: recovery must come up on
    // exactly the live set of SOME epoch prefix — records are atomic
    let mut rng = Xoshiro256pp::new(0x7EA4);
    for case in 0..8 {
        let s = arb_schedule(&mut rng);
        let dir = fresh_dir("torn");
        {
            let engine = ShardedDynamicMatcher::new(s.n, 2, 4);
            let (mut wal, _) =
                Wal::open(&recovery::wal_dir(&dir), WalOptions::default()).unwrap();
            for (i, ups) in s.epochs.iter().enumerate() {
                wal.append_epoch(i as u64 + 1, ups).unwrap();
                engine.apply_epoch(ups).unwrap();
            }
        }
        // tear the tail: the wal dir holds exactly one segment here
        let seg = std::fs::read_dir(recovery::wal_dir(&dir))
            .unwrap()
            .flatten()
            .find(|e| e.file_name().to_string_lossy().starts_with("wal-"))
            .unwrap()
            .path();
        let len = std::fs::metadata(&seg).unwrap().len();
        let cut = 9 + rng.next_usize((len as usize).saturating_sub(9));
        let f = std::fs::OpenOptions::new().write(true).open(&seg).unwrap();
        f.set_len(len - cut as u64).unwrap();
        drop(f);

        let recovered = ShardedDynamicMatcher::new(s.n, 2, 4);
        let (_, report) =
            recovery::recover(&recovered, &dir, WalOptions::default()).unwrap();
        let k = report.replayed_epochs as usize;
        assert!(k < s.epochs.len(), "case {case}: a real tear dropped ≥1 epoch");
        let mut got = recovered.live_edges();
        got.sort_unstable();
        if k == 0 {
            assert!(got.is_empty(), "case {case}");
        } else {
            assert_eq!(got, s.live_after[k - 1], "case {case}: prefix of {k} epochs");
            verify_maximal_dynamic(s.n, got.iter().copied(), &recovered.matching_pairs())
                .unwrap();
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// The retention rule end-to-end: keep the newest TWO snapshots and prune
/// the WAL lagged one snapshot behind publication. Bit-rot the newest
/// snapshot after a crash — recovery must fall back to the predecessor and
/// replay the (un-pruned) WAL suffix to the *identical* live set, because
/// the WAL behind the predecessor is exactly what the lagged prune kept.
#[test]
fn corrupt_newest_snapshot_falls_back_to_predecessor_and_replays() {
    let mut rng = Xoshiro256pp::new(0xFA11);
    for case in 0..8 {
        let s = arb_schedule(&mut rng);
        // the two retained snapshot epochs: predecessor a < newest b
        // (a = 0 models "no predecessor": the corrupt-newest fallback then
        // lands on nothing and the whole WAL replays)
        let b = 1 + rng.next_usize(s.crash_after) as u64;
        let a = rng.next_usize(b as usize) as u64;
        let dir = fresh_dir("retention");
        {
            let engine = ShardedDynamicMatcher::new(s.n, 2, 4);
            // tiny segments force rotation, so the lagged prune really
            // deletes covered segments instead of being a no-op
            let opts = WalOptions { fsync: false, segment_bytes: 256 };
            let (mut wal, _) = Wal::open(&recovery::wal_dir(&dir), opts).unwrap();
            let snap_dir = recovery::snapshot_dir(&dir);
            std::fs::create_dir_all(&snap_dir).unwrap();
            for (i, ups) in s.epochs.iter().take(s.crash_after).enumerate() {
                let e = i as u64 + 1;
                wal.append_epoch(e, ups).unwrap();
                engine.apply_epoch(ups).unwrap();
                if e == a || e == b {
                    let data = SnapshotData::capture(&engine);
                    snapshot::write_file(&snap_dir.join(snapshot::file_name(e)), &data)
                        .unwrap();
                    if e == b && a > 0 {
                        // prune-after-publish, lagged by one: only the WAL
                        // the PREDECESSOR covers may go
                        wal.prune_below(a);
                    }
                }
            }
        } // crash

        // bit-rot the newest snapshot
        let newest = recovery::snapshot_dir(&dir).join(snapshot::file_name(b));
        let mut bytes = std::fs::read(&newest).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&newest, &bytes).unwrap();

        let recovered = ShardedDynamicMatcher::new(s.n, 2, 4);
        let (_wal, report) =
            recovery::recover(&recovered, &dir, WalOptions::default()).unwrap();
        assert_eq!(
            report.replayed_epochs,
            s.crash_after as u64 - a,
            "case {case}: fell back past corrupt epoch-{b} snapshot to {a}"
        );
        let mut got = recovered.live_edges();
        got.sort_unstable();
        assert_eq!(got, s.live_after[s.crash_after - 1], "case {case}: live set");
        assert_eq!(recovered.epochs_applied(), s.crash_after as u64, "case {case}");
        verify_maximal_dynamic(s.n, got.iter().copied(), &recovered.matching_pairs())
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// SHUTDOWN-then-restart through the real service: the final snapshot
/// alone carries the state — zero WAL replay — and the exact matching
/// survives the restart.
#[test]
fn service_shutdown_then_restart_recovers_from_snapshot_alone() {
    for shards in [1usize, 4] {
        let dir = fresh_dir("service");
        let cfg = ServiceConfig {
            num_vertices: 64,
            threads: 1,
            engine_shards: shards,
            data_dir: Some(dir.to_string_lossy().into_owned()),
            ..Default::default()
        };
        // session 1: mixed epochs, ends with SHUTDOWN (graceful drain)
        let script = "\
INSERT 0 1 1 2 2 3 3 4 10 11 40 41\n\
EPOCH\n\
DELETE 1 2 10 11\n\
EPOCH\n\
QUERY 0\n\
SHUTDOWN\n";
        let mut out = Vec::new();
        let summary = serve_lines(&cfg, script.as_bytes(), &mut out).unwrap();
        assert!(summary.maximal, "P={shards}");
        assert_eq!(summary.epochs, 2, "P={shards}");
        assert_eq!(summary.wal_epochs, 2, "P={shards}");
        assert_eq!(summary.last_snapshot_epoch, 2, "P={shards}: final snapshot");
        let first = String::from_utf8(out).unwrap();
        let partner_line = first
            .lines()
            .find(|l| l.contains(r#""op":"query""#))
            .unwrap()
            .to_string();

        // session 2: restart over the same data dir
        let mut out = Vec::new();
        let summary =
            serve_lines(&cfg, "STATS full\nQUERY 0\nQUIT\n".as_bytes(), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let stats = text.lines().find(|l| l.contains(r#""op":"stats""#)).unwrap();
        assert!(
            stats.contains(r#""recovery_replayed":0"#),
            "P={shards}: snapshot-only recovery: {stats}"
        );
        assert!(stats.contains(r#""durable":true"#), "P={shards}: {stats}");
        assert!(stats.contains(r#""epochs":2"#), "P={shards}: {stats}");
        assert!(stats.contains(r#""live_edges":4"#), "P={shards}: {stats}");
        assert!(stats.contains(r#""maximal":true"#), "P={shards}: {stats}");
        // the exact matching survived: QUERY 0 answers identically
        let requeried = text
            .lines()
            .find(|l| l.contains(r#""op":"query""#))
            .unwrap();
        assert_eq!(requeried, partner_line, "P={shards}");
        assert!(summary.maximal, "P={shards}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
