//! Property-based tests over the matching invariants (qcheck substrate):
//! for arbitrary random graphs, every algorithm must emit a valid maximal
//! matching; Skipper must do so under any thread count and scheduler
//! assignment; matching sizes obey the 2-approximation bound.

use skipper::graph::gen::{barabasi_albert, erdos_renyi, rmat, GenConfig};
use skipper::graph::CsrGraph;
use skipper::matching::ems::{
    auer_bisseling::AuerBisseling, birn::Birn, idmm::Idmm, israeli_itai::IsraeliItai, pbmm::Pbmm,
    sidmm::Sidmm,
};
use skipper::matching::sgmm::Sgmm;
use skipper::matching::skipper::Skipper;
use skipper::matching::{verify, MaximalMatcher};
use skipper::par::scheduler::Assignment;
use skipper::util::qcheck::{check, Config};
use skipper::util::rng::Xoshiro256pp;

/// Random graph family: mixes ER / RMAT / BA with random sizes.
fn arb_graph(rng: &mut Xoshiro256pp) -> CsrGraph {
    match rng.next_usize(3) {
        0 => {
            let n = 16 + rng.next_usize(512);
            let m = n * (1 + rng.next_usize(8));
            erdos_renyi::generate(n, m, rng.next_u64())
        }
        1 => rmat::generate(&GenConfig {
            scale: 5 + rng.next_usize(5) as u32,
            avg_degree: 2 + rng.next_usize(10) as u32,
            seed: rng.next_u64(),
        }),
        _ => {
            let n = 16 + rng.next_usize(512);
            barabasi_albert::generate(n, 1 + rng.next_usize(5), rng.next_u64())
        }
    }
}

fn prop_cfg(cases: usize, seed: u64) -> Config {
    Config {
        cases,
        seed,
        max_shrink_steps: 0, // graphs don't shrink meaningfully
    }
}

#[test]
fn prop_all_algorithms_valid_and_maximal() {
    check(&prop_cfg(24, 0xAB01), arb_graph, |g| {
        let algos: Vec<Box<dyn MaximalMatcher>> = vec![
            Box::new(Sgmm),
            Box::new(Skipper::new(3)),
            Box::new(Sidmm::default()),
            Box::new(Idmm::default()),
            Box::new(Pbmm::default()),
            Box::new(IsraeliItai::default()),
            Box::new(Birn::default()),
            Box::new(AuerBisseling::default()),
        ];
        for a in algos {
            let m = a.run(g);
            verify::check(g, &m).map_err(|e| format!("{}: {e}", a.name()))?;
        }
        Ok(())
    });
}

#[test]
fn prop_skipper_any_thread_count_and_assignment() {
    check(&prop_cfg(24, 0xAB02), arb_graph, |g| {
        let mut rng = Xoshiro256pp::new(g.num_edge_slots() as u64);
        let t = 1 + rng.next_usize(16);
        let a = [
            Assignment::DispersedContiguous,
            Assignment::Interleaved,
            Assignment::SharedQueue,
        ][rng.next_usize(3)];
        let m = Skipper::new(t).with_assignment(a).run(g);
        verify::check(g, &m).map_err(|e| format!("t={t} {a:?}: {e}"))
    });
}

#[test]
fn prop_two_approximation_bound() {
    // any maximal matching is a 2-approximation of maximum matching, so
    // two maximal matchings differ by at most 2x.
    check(&prop_cfg(20, 0xAB03), arb_graph, |g| {
        let a = Sgmm.run(g).len();
        let b = Skipper::new(4).run(g).len();
        if a == 0 && b == 0 {
            return Ok(());
        }
        if a * 2 < b || b * 2 < a {
            return Err(format!("sizes {a} vs {b} violate 2-approx"));
        }
        Ok(())
    });
}

#[test]
fn prop_matched_vertices_cover_all_edges() {
    // direct statement of maximality on the edge level
    check(&prop_cfg(16, 0xAB04), arb_graph, |g| {
        let m = Skipper::new(2).run(g);
        let mut matched = vec![false; g.num_vertices()];
        for (u, v) in m.iter() {
            matched[u as usize] = true;
            matched[v as usize] = true;
        }
        for (v, u) in g.iter_edges() {
            if v != u && !matched[v as usize] && !matched[u as usize] {
                return Err(format!("edge ({v},{u}) uncovered"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_conflict_totals_bounded_by_work() {
    // CAS retries are charged to vertex state transitions: total conflicts
    // cannot exceed a small multiple of |V| + |E| (§V-B worst case O(t|V|)).
    check(&prop_cfg(12, 0xAB05), arb_graph, |g| {
        let rep = Skipper::new(8).run_with_conflicts(g);
        let bound = 8 * (g.num_vertices() as u64 + g.num_edge_slots() as u64);
        if rep.conflicts.total > bound {
            return Err(format!(
                "conflicts {} exceed bound {bound}",
                rep.conflicts.total
            ));
        }
        Ok(())
    });
}
