//! Property tests for the fully dynamic engine: for random insert/delete
//! schedules over every generator family, after EVERY epoch the maintained
//! matching must be (a) a subset of the live edge set, (b) endpoint-
//! disjoint, and (c) maximal over the live edges — checked with
//! `verify_maximal_dynamic`, the deletion-aware verifier, against an
//! independently maintained model of the live edge set.
//!
//! Every schedule is replayed at `engine_shards ∈ {1, 2, 4}` on the pooled
//! engine — the single-shard reference and two vertex-partitioned
//! configurations whose mutate phases run on the persistent shard-worker
//! pool — plus once at `P = 4` under the forked (`ShardExec::Fork`)
//! baseline, and each replay is cross-checked against the same live-graph
//! model. Matchings may legitimately differ between shard counts
//! (fresh-edge delivery order differs), but the live set must agree exactly
//! and every invariant must hold at every shard count and under either
//! dispatch policy.

use skipper::dynamic::{AdjLayout, PinPolicy, ShardExec, ShardedDynamicMatcher, Update};
use skipper::graph::gen::{barabasi_albert, erdos_renyi, grid};
use skipper::matching::verify::verify_maximal_dynamic;
use skipper::util::qcheck::{check, Config};
use skipper::util::rng::Xoshiro256pp;
use skipper::VertexId;

/// Shard counts every schedule is replayed at.
const SHARD_SWEEP: [usize; 3] = [1, 2, 4];

#[derive(Clone, Debug)]
struct Schedule {
    family: &'static str,
    n: usize,
    /// Edge population the schedule draws from.
    population: Vec<(VertexId, VertexId)>,
    /// Per-epoch update counts and the delete bias in percent.
    epochs: usize,
    batch: usize,
    delete_pct: usize,
    threads: usize,
    seed: u64,
}

fn arb_schedule(rng: &mut Xoshiro256pp) -> Schedule {
    let pick = rng.next_usize(3);
    let (family, n, el) = match pick {
        0 => {
            let n = 16 + rng.next_usize(400);
            let m = 2 * n + rng.next_usize(4 * n);
            ("er", n, erdos_renyi::edges(n, m, rng.next_u64()))
        }
        1 => {
            let n = 16 + rng.next_usize(300);
            ("ba", n, barabasi_albert::edges(n, 1 + rng.next_usize(4), rng.next_u64()))
        }
        _ => {
            let rows = 3 + rng.next_usize(18);
            let cols = 3 + rng.next_usize(18);
            ("grid", rows * cols, grid::edges(rows, cols, false))
        }
    };
    let mut population: Vec<(VertexId, VertexId)> = el
        .edges
        .iter()
        .filter(|&&(u, v)| u != v)
        .map(|&(u, v)| (u.min(v), u.max(v)))
        .collect();
    population.sort_unstable();
    population.dedup();
    rng.shuffle(&mut population);
    Schedule {
        family,
        n,
        population,
        epochs: 3 + rng.next_usize(10),
        batch: 5 + rng.next_usize(120),
        delete_pct: 20 + rng.next_usize(60),
        threads: 1 + rng.next_usize(4),
        seed: rng.next_u64(),
    }
}

/// Run the schedule at one shard count and dispatch policy; error on the
/// first invariant violation. The update stream is regenerated from
/// `s.seed`, so every configuration sees the identical schedule.
fn run_schedule_sharded(s: &Schedule, engine_shards: usize, exec: ShardExec) -> Result<(), String> {
    let tag = |msg: String| format!("{} P={engine_shards} {}: {msg}", s.family, exec.name());
    let mut rng = Xoshiro256pp::new(s.seed);
    let engine = ShardedDynamicMatcher::with_exec(s.n, s.threads, engine_shards, exec);
    // reference model of the live graph; a Vec suffices (and samples in
    // O(1)) because `pool` and `live` stay disjoint by construction, so an
    // insert can never duplicate a live edge
    let mut live: Vec<(VertexId, VertexId)> = Vec::new();
    let mut pool = s.population.clone(); // not-yet-live edges
    let mut dead: Vec<(VertexId, VertexId)> = Vec::new();

    for epoch in 0..s.epochs {
        let mut updates = Vec::with_capacity(s.batch);
        for _ in 0..s.batch {
            let deleting = !live.is_empty() && rng.next_usize(100) < s.delete_pct;
            if deleting {
                let k = rng.next_usize(live.len());
                let (u, v) = live.swap_remove(k);
                dead.push((u, v));
                updates.push(Update::Delete(u, v));
            } else {
                if pool.is_empty() {
                    pool.append(&mut dead);
                    rng.shuffle(&mut pool);
                }
                match pool.pop() {
                    Some((u, v)) => {
                        live.push((u, v));
                        updates.push(Update::Insert(u, v));
                    }
                    None => break, // population exhausted and nothing dead
                }
            }
        }
        let report = engine
            .apply_epoch(&updates)
            .map_err(|e| tag(format!("epoch {epoch}: {e}")))?;

        // live-set agreement between engine and model
        if engine.num_live_edges() != live.len() as u64 {
            return Err(tag(format!(
                "epoch {epoch}: engine live {} != model live {}",
                engine.num_live_edges(),
                live.len()
            )));
        }
        // matching ⊆ live ∧ endpoint-disjoint ∧ maximal — via the dynamic
        // verifier fed from the *model's* live set, so the sharded
        // adjacency slices are cross-checked too
        let pairs = engine.matching_pairs();
        verify_maximal_dynamic(s.n, live.iter().copied(), &pairs)
            .map_err(|e| tag(format!("epoch {epoch} (batch {}): {e}", s.batch)))?;
        // engine's own audit must agree
        engine
            .verify()
            .map_err(|e| tag(format!("epoch {epoch}: self-audit: {e}")))?;
        // matched-vertex bookkeeping
        if report.matched_vertices != 2 * pairs.len() {
            return Err(tag(format!(
                "epoch {epoch}: matched_vertices {} != 2×{}",
                report.matched_vertices,
                pairs.len()
            )));
        }
        // the engine's own live-edge collection must equal the model's set
        let mut got = engine.live_edges();
        got.sort_unstable();
        let mut want = live.clone();
        want.sort_unstable();
        if got != want {
            return Err(tag(format!("epoch {epoch}: live edge sets diverge")));
        }
    }
    Ok(())
}

/// Replay the schedule at every shard count in the sweep (pooled engine),
/// plus once under the forked dispatch baseline.
fn run_schedule(s: &Schedule) -> Result<(), String> {
    for &p in &SHARD_SWEEP {
        run_schedule_sharded(s, p, ShardExec::Pool)?;
    }
    run_schedule_sharded(s, 4, ShardExec::Fork)?;
    Ok(())
}

/// Replay one schedule at a fixed shard count and pin policy, recording the
/// per-epoch matching and live set. `threads = 1` makes the sweep order —
/// and therefore the matching itself — deterministic, so two replays that
/// differ only in placement must produce identical trajectories.
fn run_schedule_pinned(
    s: &Schedule,
    engine_shards: usize,
    pin: PinPolicy,
) -> Vec<(Vec<(VertexId, VertexId)>, Vec<(VertexId, VertexId)>)> {
    let mut rng = Xoshiro256pp::new(s.seed);
    let engine = ShardedDynamicMatcher::with_exec_layout_pin(
        s.n,
        1,
        engine_shards,
        ShardExec::Pool,
        AdjLayout::default(),
        pin,
    );
    let mut live: Vec<(VertexId, VertexId)> = Vec::new();
    let mut pool = s.population.clone();
    let mut dead: Vec<(VertexId, VertexId)> = Vec::new();
    let mut trajectory = Vec::with_capacity(s.epochs);
    for _ in 0..s.epochs {
        let mut updates = Vec::with_capacity(s.batch);
        for _ in 0..s.batch {
            let deleting = !live.is_empty() && rng.next_usize(100) < s.delete_pct;
            if deleting {
                let k = rng.next_usize(live.len());
                let (u, v) = live.swap_remove(k);
                dead.push((u, v));
                updates.push(Update::Delete(u, v));
            } else {
                if pool.is_empty() {
                    pool.append(&mut dead);
                    rng.shuffle(&mut pool);
                }
                match pool.pop() {
                    Some((u, v)) => {
                        live.push((u, v));
                        updates.push(Update::Insert(u, v));
                    }
                    None => break,
                }
            }
        }
        engine.apply_epoch(&updates).unwrap();
        engine.verify().unwrap();
        let mut matching = engine.matching_pairs();
        matching.sort_unstable();
        let mut live_now = engine.live_edges();
        live_now.sort_unstable();
        trajectory.push((matching, live_now));
    }
    trajectory
}

#[test]
fn pinned_replays_are_bit_identical_to_unpinned() {
    // pinning relocates workers and first-touches memory on their nodes; it
    // must never change a single matching decision. Whole trajectories —
    // matching AND live set after every epoch — are compared at P ∈
    // {1, 4, 8} between the unpinned engine and both pin policies, on
    // whatever topology the host has (single-node fallback included).
    check(
        &Config { cases: 12, seed: 0x91AA, ..Default::default() },
        arb_schedule,
        |s| {
            for p in [1usize, 4, 8] {
                let base = run_schedule_pinned(s, p, PinPolicy::None);
                for pin in [PinPolicy::Compact, PinPolicy::Spread] {
                    let pinned = run_schedule_pinned(s, p, pin);
                    if pinned != base {
                        return Err(format!(
                            "{} P={p}: {} trajectory diverged from unpinned",
                            s.family,
                            pin.name()
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn random_interleavings_stay_maximal_on_every_family() {
    check(
        &Config { cases: 40, ..Default::default() },
        arb_schedule,
        run_schedule,
    );
}

#[test]
fn delete_heavy_schedules_stay_maximal() {
    // deletions dominate: most epochs tear matched pairs apart, so the
    // repair sweep carries the maximality invariant almost alone
    check(
        &Config { cases: 25, seed: 0xDE1E7E, ..Default::default() },
        |rng| {
            let mut s = arb_schedule(rng);
            s.delete_pct = 75 + rng.next_usize(21); // 75..=95
            s
        },
        run_schedule,
    );
}

#[test]
fn drain_to_empty_then_refill_stays_maximal() {
    // insert everything, delete everything (matching must end empty), then
    // refill — exercises repair down to the empty graph and back, at every
    // shard count in the sweep
    let el = erdos_renyi::edges(200, 800, 3);
    let mut population: Vec<(VertexId, VertexId)> = el
        .edges
        .iter()
        .filter(|&&(u, v)| u != v)
        .map(|&(u, v)| (u.min(v), u.max(v)))
        .collect();
    population.sort_unstable();
    population.dedup();
    for &p in &SHARD_SWEEP {
        let engine = ShardedDynamicMatcher::new(200, 2, p);
        let ins: Vec<Update> = population.iter().map(|&(u, v)| Update::Insert(u, v)).collect();
        engine.apply_epoch(&ins).unwrap();
        engine.verify().unwrap();
        assert!(engine.matched_vertices() > 0, "P={p}");
        for chunk in population.chunks(97) {
            let dels: Vec<Update> = chunk.iter().map(|&(u, v)| Update::Delete(u, v)).collect();
            engine.apply_epoch(&dels).unwrap();
            engine.verify().unwrap();
        }
        assert_eq!(engine.num_live_edges(), 0, "P={p}");
        assert_eq!(engine.matched_vertices(), 0, "P={p}: no live edges, no matches");
        assert!(engine.matching_pairs().is_empty(), "P={p}");
        engine.apply_epoch(&ins).unwrap();
        engine.verify().unwrap();
        assert!(
            engine.matched_vertices() > 0,
            "P={p}: engine recovers after total drain"
        );
    }
}
