//! Integration: the experiment coordinator end-to-end on one tiny dataset —
//! metric collection, every table/figure renderer, config parsing, report
//! writing. The paper's *shape* claims are asserted where they are scale-
//! independent.

use skipper::apram::cost::CostModel;
use skipper::coordinator::config::RunConfig;
use skipper::coordinator::datasets::{spec_by_name, Scale};
use skipper::coordinator::experiments::{self as exp, collect_dataset};
use skipper::coordinator::report::Report;

fn metrics() -> Vec<exp::DatasetMetrics> {
    let dir = std::env::temp_dir().join("skipper_it_exp");
    let dir = dir.to_str().unwrap();
    vec![
        collect_dataset(spec_by_name("twitter10s").unwrap(), Scale::Tiny, dir, 2),
        collect_dataset(spec_by_name("g500s").unwrap(), Scale::Tiny, dir, 2),
    ]
}

#[test]
fn full_experiment_pipeline() {
    let m = metrics();
    let cost = CostModel::default();
    let mut report = Report::new();
    report.add("table1", exp::table1(&m, &cost));
    report.add("table2", exp::table2(&m));
    report.add("fig3", exp::fig3(&m, &cost));
    report.add("fig7", exp::fig7(&m));
    report.add("fig8", exp::fig8(&m));
    report.add("fig9", exp::fig9(&m, &cost));
    report.add("fig10", exp::fig10(&m, &cost));
    report.add("fig11", exp::fig11(&m));
    // every section mentions both datasets
    for (id, content) in report.sections() {
        assert!(content.contains("twitter10"), "{id} missing twitter10");
        assert!(content.contains("g500"), "{id} missing g500");
    }
    // reports write out
    let dir = std::env::temp_dir().join("skipper_it_reports");
    let dir_s = dir.to_str().unwrap();
    let _ = std::fs::remove_dir_all(dir_s);
    let files = report.write_dir(dir_s).unwrap();
    assert_eq!(files.len(), 9); // 8 sections + summary.md
    let _ = std::fs::remove_dir_all(dir_s);
}

#[test]
fn paper_shape_claims_on_tiny_suite() {
    let ms = metrics();
    let cost = CostModel::default();
    for m in &ms {
        let name = m.spec.name;
        // Fig 7 shape: SGMM < Skipper << SIDMM accesses
        assert!(
            m.sgmm_accesses < m.skipper_accesses_1t,
            "{name}: SGMM should touch less than Skipper"
        );
        assert!(
            m.sidmm_accesses > 5 * m.skipper_accesses_1t,
            "{name}: SIDMM overhead missing ({} vs {})",
            m.sidmm_accesses,
            m.skipper_accesses_1t
        );
        // Table I shape: Skipper wins at t=64
        let speedup = m.sidmm_par_seconds(&cost, 64) / m.skipper_par_seconds(&cost, 64);
        assert!(speedup > 2.0, "{name}: Table I speedup only {speedup:.2}");
        // Table II shape: conflicts are rare
        let ratio = m.conflicts64.edges_with_conflicts as f64 / m.e_slots as f64;
        assert!(ratio < 0.02, "{name}: conflict ratio {ratio}");
        // Fig 11 shape: Skipper's serial slowdown is far below SIDMM's
        let sk = m.skipper_wall_1t_s / m.sgmm_wall_s;
        let sd = m.sidmm_wall_s / m.sgmm_wall_s;
        assert!(
            sk < sd,
            "{name}: skipper serial slowdown {sk:.2} not below SIDMM {sd:.2}"
        );
    }
}

#[test]
fn config_roundtrip_drives_pipeline() {
    let cfg = RunConfig::parse(
        r#"
        scale = "tiny"
        table2_runs = 1
        datasets = ["twitter10s"]
        "#,
    )
    .unwrap();
    assert_eq!(cfg.scale, Scale::Tiny);
    assert_eq!(cfg.datasets, vec!["twitter10s"]);
}
