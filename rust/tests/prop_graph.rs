//! Property tests on the graph substrate: builder invariants (symmetry,
//! sorted+deduped neighbor lists, degree conservation), IO round-trips, and
//! relabeling invariance.

use skipper::graph::builder::{build, relabel, to_edge_list, BuildOptions};
use skipper::graph::io::{binary, edgelist_txt, mtx};
use skipper::graph::{CsrGraph, EdgeList};
use skipper::util::qcheck::{check, Config};
use skipper::util::rng::Xoshiro256pp;

fn arb_edge_list(rng: &mut Xoshiro256pp) -> EdgeList {
    let n = 2 + rng.next_usize(300);
    let m = rng.next_usize(4 * n);
    let mut el = EdgeList::new(n);
    for _ in 0..m {
        el.push(rng.next_usize(n) as u32, rng.next_usize(n) as u32);
    }
    el
}

fn cfg(seed: u64) -> Config {
    Config {
        cases: 40,
        seed,
        max_shrink_steps: 0,
    }
}

#[test]
fn prop_builder_produces_canonical_csr() {
    check(&cfg(0x6701), arb_edge_list, |el| {
        let g = build(el, BuildOptions::default());
        if !g.is_symmetric() {
            return Err("not symmetric".into());
        }
        for v in 0..g.num_vertices() as u32 {
            let ns = g.neighbors(v);
            if ns.windows(2).any(|w| w[0] >= w[1]) {
                return Err(format!("neighbors of {v} not sorted+deduped: {ns:?}"));
            }
            if ns.contains(&v) {
                return Err(format!("self-loop survived at {v}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_edge_conservation_without_dedup() {
    // without dedup/self-loop-dropping, every input edge contributes
    // exactly two slots (or one for self-loops).
    check(&cfg(0x6702), arb_edge_list, |el| {
        let g = build(
            el,
            BuildOptions {
                symmetrize: true,
                dedup: false,
                drop_self_loops: true,
            },
        );
        let loops = el.edges.iter().filter(|(u, v)| u == v).count();
        let expect = 2 * (el.edges.len() - loops);
        if g.num_edge_slots() != expect {
            return Err(format!("slots {} != {expect}", g.num_edge_slots()));
        }
        Ok(())
    });
}

#[test]
fn prop_binary_io_roundtrip() {
    check(&cfg(0x6703), arb_edge_list, |el| {
        let g = build(el, BuildOptions::default());
        let mut buf = Vec::new();
        binary::write(&mut buf, &g).map_err(|e| e.to_string())?;
        let back = binary::read(&buf[..]).map_err(|e| e.to_string())?;
        if back != g {
            return Err("binary roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_text_io_roundtrips() {
    check(&cfg(0x6704), arb_edge_list, |el| {
        // edge-list text
        let mut buf = Vec::new();
        edgelist_txt::write(&mut buf, el).map_err(|e| e.to_string())?;
        let back = edgelist_txt::read(&buf[..])?;
        if back != *el {
            return Err("edgelist roundtrip mismatch".into());
        }
        // matrix market
        let mut buf = Vec::new();
        mtx::write(&mut buf, el).map_err(|e| e.to_string())?;
        let back = mtx::read(&buf[..])?;
        if back != *el {
            return Err("mtx roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_relabel_preserves_degree_multiset() {
    check(&cfg(0x6705), arb_edge_list, |el| {
        let g = build(el, BuildOptions::default());
        let mut rng = Xoshiro256pp::new(el.edges.len() as u64 + 1);
        let perm = rng.permutation(g.num_vertices());
        let g2 = relabel(&g, &perm);
        let mut d1: Vec<usize> = (0..g.num_vertices() as u32).map(|v| g.degree(v)).collect();
        let mut d2: Vec<usize> = (0..g2.num_vertices() as u32).map(|v| g2.degree(v)).collect();
        d1.sort_unstable();
        d2.sort_unstable();
        if d1 != d2 {
            return Err("degree multiset changed under relabeling".into());
        }
        Ok(())
    });
}

#[test]
fn prop_to_edge_list_canonical_and_complete() {
    check(&cfg(0x6706), arb_edge_list, |el| {
        let g = build(el, BuildOptions::default());
        let canon = to_edge_list(&g);
        if canon.edges.len() != g.num_undirected_edges() {
            return Err("canonical edge count mismatch".into());
        }
        for &(u, v) in &canon.edges {
            if u > v {
                return Err(format!("non-canonical edge ({u},{v})"));
            }
        }
        // rebuilding from the canonical list reproduces the graph
        let g2 = build(&canon, BuildOptions::default());
        if g2 != g {
            return Err("rebuild from canonical list differs".into());
        }
        Ok(())
    });
}

#[test]
fn prop_binary_roundtrip_across_all_generator_families() {
    // the snapshot encoding of the persistence layer rests on the `.skg`
    // conventions, so the binary round-trip must hold for every generator
    // family the suite (and the churn driver) can produce — not just the
    // uniform random edge lists above
    use skipper::graph::gen::{
        barabasi_albert, erdos_renyi, grid, hostweb, knn_overlap, rmat, watts_strogatz,
        GenConfig,
    };
    let roundtrip = |g: &CsrGraph| -> Result<(), String> {
        let mut buf = Vec::new();
        binary::write(&mut buf, g).map_err(|e| e.to_string())?;
        let back = binary::read(&buf[..]).map_err(|e| e.to_string())?;
        if &back != g {
            return Err("binary roundtrip mismatch".into());
        }
        Ok(())
    };
    check(
        &cfg(0x6708),
        |rng| {
            let seed = rng.next_u64();
            match rng.next_usize(7) {
                0 => {
                    let n = 8 + rng.next_usize(200);
                    ("er", erdos_renyi::generate(n, 2 * n + rng.next_usize(4 * n), seed))
                }
                1 => {
                    let n = 8 + rng.next_usize(200);
                    ("ba", barabasi_albert::generate(n, 1 + rng.next_usize(4), seed))
                }
                2 => ("grid", grid::generate(
                    2 + rng.next_usize(16),
                    2 + rng.next_usize(16),
                    rng.next_usize(2) == 0,
                )),
                3 => ("rmat", rmat::generate(&GenConfig {
                    scale: 4 + rng.next_usize(5) as u32,
                    avg_degree: 1 + rng.next_usize(8) as u32,
                    seed,
                })),
                4 => {
                    let k = 1 + rng.next_usize(4);
                    ("ws", watts_strogatz::generate(&watts_strogatz::WsConfig {
                        n: 2 * k + 2 + rng.next_usize(150),
                        k,
                        beta: rng.next_usize(100) as f64 / 100.0,
                        seed,
                    }))
                }
                5 => ("knn", knn_overlap::generate(&knn_overlap::KnnConfig {
                    n: 8 + rng.next_usize(200),
                    k: 1 + rng.next_usize(5) as u32,
                    window: 2 + rng.next_usize(20),
                    long_range_p: rng.next_usize(100) as f64 / 200.0,
                    seed,
                })),
                _ => ("hostweb", hostweb::generate(&hostweb::HostWebConfig {
                    num_hosts: 1 + rng.next_usize(8),
                    vertices_per_host: 2 + rng.next_usize(24),
                    intra_degree: 1 + rng.next_usize(4) as u32,
                    inter_degree: rng.next_usize(4) as u32,
                    seed,
                })),
            }
        },
        |(family, g)| roundtrip(g).map_err(|e| format!("{family}: {e}")),
    );
    // the degenerate graphs every encoder forgets: empty, edgeless, and a
    // single edge
    let empty = CsrGraph::from_parts(vec![0], vec![]).unwrap();
    roundtrip(&empty).unwrap();
    let edgeless = CsrGraph::from_parts(vec![0, 0, 0, 0], vec![]).unwrap();
    roundtrip(&edgeless).unwrap();
    let single = CsrGraph::from_parts(vec![0, 1, 2], vec![1, 0]).unwrap();
    roundtrip(&single).unwrap();
}

#[test]
fn prop_csr_from_parts_validates_random_corruption() {
    // corrupting a valid CSR is caught by from_parts
    check(&cfg(0x6707), arb_edge_list, |el| {
        let g = build(el, BuildOptions::default());
        if g.num_edge_slots() == 0 {
            return Ok(());
        }
        let mut offsets = g.offsets().to_vec();
        let last = offsets.len() - 1;
        offsets[last] += 1; // break the slot-count invariant
        if CsrGraph::from_parts(offsets, g.neighbors_raw().to_vec()).is_ok() {
            return Err("corrupted offsets accepted".into());
        }
        Ok(())
    });
}
