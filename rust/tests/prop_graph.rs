//! Property tests on the graph substrate: builder invariants (symmetry,
//! sorted+deduped neighbor lists, degree conservation), IO round-trips, and
//! relabeling invariance.

use skipper::graph::builder::{build, relabel, to_edge_list, BuildOptions};
use skipper::graph::io::{binary, edgelist_txt, mtx};
use skipper::graph::{CsrGraph, EdgeList};
use skipper::util::qcheck::{check, Config};
use skipper::util::rng::Xoshiro256pp;

fn arb_edge_list(rng: &mut Xoshiro256pp) -> EdgeList {
    let n = 2 + rng.next_usize(300);
    let m = rng.next_usize(4 * n);
    let mut el = EdgeList::new(n);
    for _ in 0..m {
        el.push(rng.next_usize(n) as u32, rng.next_usize(n) as u32);
    }
    el
}

fn cfg(seed: u64) -> Config {
    Config {
        cases: 40,
        seed,
        max_shrink_steps: 0,
    }
}

#[test]
fn prop_builder_produces_canonical_csr() {
    check(&cfg(0x6701), arb_edge_list, |el| {
        let g = build(el, BuildOptions::default());
        if !g.is_symmetric() {
            return Err("not symmetric".into());
        }
        for v in 0..g.num_vertices() as u32 {
            let ns = g.neighbors(v);
            if ns.windows(2).any(|w| w[0] >= w[1]) {
                return Err(format!("neighbors of {v} not sorted+deduped: {ns:?}"));
            }
            if ns.contains(&v) {
                return Err(format!("self-loop survived at {v}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_edge_conservation_without_dedup() {
    // without dedup/self-loop-dropping, every input edge contributes
    // exactly two slots (or one for self-loops).
    check(&cfg(0x6702), arb_edge_list, |el| {
        let g = build(
            el,
            BuildOptions {
                symmetrize: true,
                dedup: false,
                drop_self_loops: true,
            },
        );
        let loops = el.edges.iter().filter(|(u, v)| u == v).count();
        let expect = 2 * (el.edges.len() - loops);
        if g.num_edge_slots() != expect {
            return Err(format!("slots {} != {expect}", g.num_edge_slots()));
        }
        Ok(())
    });
}

#[test]
fn prop_binary_io_roundtrip() {
    check(&cfg(0x6703), arb_edge_list, |el| {
        let g = build(el, BuildOptions::default());
        let mut buf = Vec::new();
        binary::write(&mut buf, &g).map_err(|e| e.to_string())?;
        let back = binary::read(&buf[..]).map_err(|e| e.to_string())?;
        if back != g {
            return Err("binary roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_text_io_roundtrips() {
    check(&cfg(0x6704), arb_edge_list, |el| {
        // edge-list text
        let mut buf = Vec::new();
        edgelist_txt::write(&mut buf, el).map_err(|e| e.to_string())?;
        let back = edgelist_txt::read(&buf[..])?;
        if back != *el {
            return Err("edgelist roundtrip mismatch".into());
        }
        // matrix market
        let mut buf = Vec::new();
        mtx::write(&mut buf, el).map_err(|e| e.to_string())?;
        let back = mtx::read(&buf[..])?;
        if back != *el {
            return Err("mtx roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_relabel_preserves_degree_multiset() {
    check(&cfg(0x6705), arb_edge_list, |el| {
        let g = build(el, BuildOptions::default());
        let mut rng = Xoshiro256pp::new(el.edges.len() as u64 + 1);
        let perm = rng.permutation(g.num_vertices());
        let g2 = relabel(&g, &perm);
        let mut d1: Vec<usize> = (0..g.num_vertices() as u32).map(|v| g.degree(v)).collect();
        let mut d2: Vec<usize> = (0..g2.num_vertices() as u32).map(|v| g2.degree(v)).collect();
        d1.sort_unstable();
        d2.sort_unstable();
        if d1 != d2 {
            return Err("degree multiset changed under relabeling".into());
        }
        Ok(())
    });
}

#[test]
fn prop_to_edge_list_canonical_and_complete() {
    check(&cfg(0x6706), arb_edge_list, |el| {
        let g = build(el, BuildOptions::default());
        let canon = to_edge_list(&g);
        if canon.edges.len() != g.num_undirected_edges() {
            return Err("canonical edge count mismatch".into());
        }
        for &(u, v) in &canon.edges {
            if u > v {
                return Err(format!("non-canonical edge ({u},{v})"));
            }
        }
        // rebuilding from the canonical list reproduces the graph
        let g2 = build(&canon, BuildOptions::default());
        if g2 != g {
            return Err("rebuild from canonical list differs".into());
        }
        Ok(())
    });
}

#[test]
fn prop_csr_from_parts_validates_random_corruption() {
    // corrupting a valid CSR is caught by from_parts
    check(&cfg(0x6707), arb_edge_list, |el| {
        let g = build(el, BuildOptions::default());
        if g.num_edge_slots() == 0 {
            return Ok(());
        }
        let mut offsets = g.offsets().to_vec();
        let last = offsets.len() - 1;
        offsets[last] += 1; // break the slot-count invariant
        if CsrGraph::from_parts(offsets, g.neighbors_raw().to_vec()).is_ok() {
            return Err("corrupted offsets accepted".into());
        }
        Ok(())
    });
}
