//! Whole-process service tests, driving the real `skipper-cli` binary:
//! coordinator-panic containment (a router/flusher panic must exit the
//! process with a diagnostic instead of leaving clients hanging) and the
//! `kill -9` → restart → recovery path the durability subsystem exists
//! for. Everything runs over stdio pipes, so no sockets are needed.

use std::io::{BufRead, BufReader, Write};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_skipper-cli")
}

static DIR_ID: AtomicU64 = AtomicU64::new(0);

fn fresh_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "skipper_itest_{}_{}_{}",
        std::process::id(),
        tag,
        DIR_ID.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Wait for the child to exit, failing the test instead of hanging forever.
fn wait_with_timeout(child: &mut Child, secs: u64) -> std::process::ExitStatus {
    let deadline = Instant::now() + Duration::from_secs(secs);
    loop {
        if let Some(status) = child.try_wait().expect("try_wait") {
            return status;
        }
        if Instant::now() >= deadline {
            let _ = child.kill();
            panic!("server did not exit within {secs}s");
        }
        std::thread::sleep(Duration::from_millis(25));
    }
}

fn spawn_serve(args: &[&str]) -> Child {
    Command::new(bin())
        .arg("serve")
        .args(args)
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn skipper-cli serve")
}

/// A coordinator-thread panic must become a prompt, diagnosed process exit
/// (code 70) — not a hung server. Covers the router and, separately, the
/// flusher (which runs on its own thread under the default pipelining).
#[test]
fn coordinator_panic_exits_the_process_with_a_diagnostic() {
    for target in ["router", "flusher"] {
        let mut child = spawn_serve(&["--vertices", "64", "--debug-commands"]);
        {
            let stdin = child.stdin.as_mut().unwrap();
            // a real update first, so the panic hits a live coordinator
            writeln!(stdin, "INSERT 0 1").unwrap();
            writeln!(stdin, "CRASH {target}").unwrap();
            stdin.flush().unwrap();
            // keep stdin OPEN: an EOF would be a normal shutdown and mask
            // a server that ignored the crash
        }
        let status = wait_with_timeout(&mut child, 30);
        assert_eq!(status.code(), Some(70), "{target}: wrong exit code");
        let mut stderr = String::new();
        std::io::Read::read_to_string(child.stderr.as_mut().unwrap(), &mut stderr).unwrap();
        assert!(
            stderr.contains(&format!("service {target} thread panicked")),
            "{target}: missing diagnostic in stderr:\n{stderr}"
        );
        assert!(
            stderr.contains("deliberate"),
            "{target}: original panic message not surfaced:\n{stderr}"
        );
    }
}

/// A panic inside a *durable* service additionally dumps a crash blackbox
/// — one JSON artifact carrying the full metrics exposition and the recent
/// span trace — into the data dir before the exit(70).
#[test]
fn coordinator_panic_leaves_a_parseable_blackbox_artifact() {
    let dir = fresh_dir("blackbox");
    let dir_s = dir.to_string_lossy().into_owned();
    let mut child = spawn_serve(&[
        "--vertices",
        "64",
        "--debug-commands",
        "--trace",
        "--data-dir",
        &dir_s,
    ]);
    {
        let stdin = child.stdin.as_mut().unwrap();
        writeln!(stdin, "INSERT 0 1").unwrap();
        writeln!(stdin, "EPOCH").unwrap();
        writeln!(stdin, "CRASH flusher").unwrap();
        stdin.flush().unwrap();
        // keep stdin open — see coordinator_panic_exits_the_process
    }
    let status = wait_with_timeout(&mut child, 30);
    assert_eq!(status.code(), Some(70), "wrong exit code");
    let mut stderr = String::new();
    std::io::Read::read_to_string(child.stderr.as_mut().unwrap(), &mut stderr).unwrap();
    assert!(
        stderr.contains("blackbox written to"),
        "dump not reported in stderr:\n{stderr}"
    );
    let artifact = std::fs::read_dir(&dir)
        .expect("data dir survives the crash")
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .find(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("blackbox-") && n.ends_with(".json"))
        })
        .unwrap_or_else(|| panic!("no blackbox-*.json in {}; stderr:\n{stderr}", dir.display()));
    let text = std::fs::read_to_string(&artifact).expect("read artifact");
    let doc = skipper::util::json::parse(&text).expect("artifact must parse");
    assert_eq!(
        doc.get("schema").and_then(|j| j.as_str()),
        Some("skipper-blackbox-v1"),
        "{text}"
    );
    assert_eq!(doc.get("role").and_then(|j| j.as_str()), Some("flusher"), "{text}");
    let metrics = doc.get("metrics").and_then(|j| j.as_str()).expect("metrics string");
    assert!(metrics.contains("skipper_"), "exposition embedded:\n{metrics}");
    let trace = doc.get("trace").expect("trace document embedded");
    assert!(trace.get("traceEvents").and_then(|j| j.as_arr()).is_some(), "{text}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Without `--debug-commands`, `CRASH` is refused and the server lives on.
#[test]
fn crash_command_requires_the_debug_flag() {
    let mut child = spawn_serve(&["--vertices", "16"]);
    {
        let stdin = child.stdin.as_mut().unwrap();
        writeln!(stdin, "CRASH router").unwrap();
        writeln!(stdin, "QUIT").unwrap();
        stdin.flush().unwrap();
    }
    let status = wait_with_timeout(&mut child, 30);
    assert!(status.success(), "server must survive a refused CRASH");
    let mut out = String::new();
    std::io::Read::read_to_string(child.stdout.as_mut().unwrap(), &mut out).unwrap();
    assert!(out.contains("--debug-commands"), "{out}");
}

/// The acceptance crash: SIGKILL the server mid-stream (after confirmed
/// epoch replies, so the WAL provably holds them), restart over the same
/// data dir, and check that recovery replayed every epoch and the state is
/// exactly right.
#[test]
fn kill_dash_nine_then_restart_replays_the_wal() {
    let dir = fresh_dir("kill9");
    let dir_s = dir.to_string_lossy().into_owned();
    let mut child = spawn_serve(&["--vertices", "256", "--threads", "1", "--data-dir", &dir_s]);
    {
        let stdin = child.stdin.as_mut().unwrap();
        write!(
            stdin,
            "INSERT 0 1 2 3\nEPOCH\nINSERT 4 5\nEPOCH\nDELETE 0 1\nEPOCH\n"
        )
        .unwrap();
        stdin.flush().unwrap();
    }
    // read replies until all 3 epoch reports arrived: each one means the
    // epoch was logged (WAL-before-apply) AND applied
    {
        let stdout = child.stdout.as_mut().unwrap();
        let reader = BufReader::new(stdout);
        let mut epochs_seen = 0;
        for line in reader.lines() {
            let line = line.expect("server stdout");
            if line.contains(r#""op":"epoch""#) {
                epochs_seen += 1;
                if epochs_seen == 3 {
                    break;
                }
            }
        }
        assert_eq!(epochs_seen, 3, "server died before the crash point");
    }
    child.kill().expect("SIGKILL"); // kill -9: no shutdown, no final snapshot
    let _ = child.wait();

    // restart over the same data dir and interrogate the recovered state
    let output = Command::new(bin())
        .args(["serve", "--vertices", "256", "--threads", "1", "--data-dir", &dir_s])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .and_then(|mut c| {
            c.stdin
                .as_mut()
                .unwrap()
                .write_all(b"STATS full\nQUERY 4\nQUERY 0\nQUIT\n")?;
            c.wait_with_output()
        })
        .expect("restart skipper-cli serve");
    assert!(output.status.success(), "restart failed: {output:?}");
    let stdout = String::from_utf8_lossy(&output.stdout);
    let stderr = String::from_utf8_lossy(&output.stderr);
    let stats = stdout
        .lines()
        .find(|l| l.contains(r#""op":"stats""#))
        .unwrap_or_else(|| panic!("no stats line in:\n{stdout}"));
    assert!(stats.contains(r#""recovery_replayed":3"#), "{stats}");
    assert!(stats.contains(r#""epochs":3"#), "timeline resumes: {stats}");
    assert!(stats.contains(r#""live_edges":2"#), "{stats}");
    assert!(stats.contains(r#""maximal":true"#), "{stats}");
    // epoch 1 matched (0,1) and (2,3); epoch 2 matched (4,5); epoch 3
    // deleted (0,1), freeing 0 and 1 with no surviving edges to repair
    let q4 = stdout.lines().find(|l| l.contains(r#""vertex":4"#)).unwrap();
    assert!(q4.contains(r#""partner":5"#), "{q4}");
    let q0 = stdout.lines().find(|l| l.contains(r#""vertex":0"#)).unwrap();
    assert!(q0.contains(r#""matched":false"#), "{q0}");
    assert!(
        stderr.contains("replayed 3 wal epochs"),
        "recovery report missing:\n{stderr}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
